"""Shared-prefix caching + self-speculative decoding on the paged
serving stack: refcounted page sharing, copy-on-write divergence,
evict/restore and deadline expiry of sharers, greedy spec-decoding
exactness across cache layouts, and the compile-count guard for the
batched verify program.
"""

import time

import numpy as np
import pytest

from repro.kernels import registry as kreg
from repro.kernels.registry import DEFAULT_CONFIG, KernelFeatures
from repro.serving import (
    BlockAllocator,
    NgramProposer,
    PrefixIndex,
    SamplingParams,
    Scheduler,
    ServeRequest,
    ServingGateway,
)
from test_serving import _engine, _tiny_lm


# ------------------------------ allocator refcounts --------------------------


def test_allocator_refcount_lifecycle():
    a = BlockAllocator(8)
    (p,) = a.alloc(1)
    assert a.refcount(p) == 1
    a.incref(p)
    assert a.refcount(p) == 2
    assert a.decref(p) is False  # still shared
    assert a.num_in_use == 1
    assert a.decref(p) is True  # last holder frees
    assert a.num_in_use == 0 and a.num_free == a.capacity
    # Double-decref guard: the page has no live references anymore.
    with pytest.raises(ValueError, match="decref of unallocated"):
        a.decref(p)
    with pytest.raises(ValueError, match="incref of unallocated"):
        a.incref(p)


def test_allocator_revive_and_shared_free_guards():
    a = BlockAllocator(8)
    (p,) = a.alloc(1)
    with pytest.raises(ValueError, match="revive of in-use"):
        a.revive(p)  # live page: sharers must incref, not revive
    a.decref(p)
    a.revive(p)  # cached-free content reclaimed
    assert a.refcount(p) == 1
    with pytest.raises(ValueError, match="not on the free list"):
        a.revive(99)
    a.incref(p)
    with pytest.raises(ValueError, match="free of shared page"):
        a.free([p])  # hard-free must never yank a page from sharers
    freed = a.decref_all([p, p])
    assert freed == [p] and a.num_in_use == 0


# ------------------------------- prefix index --------------------------------


def test_prefix_index_match_publish_partial_and_forget():
    idx = PrefixIndex(4)
    root_pages, root, partial = idx.match(np.arange(10))
    assert root_pages == [] and partial is None
    h1 = idx.publish(root, (0, 1, 2, 3), page=5)
    h2 = idx.publish(h1, (4, 5, 6, 7), page=6)
    assert len(idx) == 2
    # Full-chain match; the prompt's last token never matches (its logits
    # must come from prefill), so a 9-token prompt matches both pages but
    # an 8-token prompt only the first.
    pages, h, partial = idx.match(np.arange(9))
    assert pages == [5, 6] and h == h2 and partial is None
    pages, h, partial = idx.match(np.arange(8))
    assert pages == [5] and h == h1
    assert partial == (6, 3)  # tokens 4,5,6 of page 6 still usable
    # Divergence mid-page surfaces the donor for copy-on-write.
    div = np.asarray([0, 1, 2, 3, 4, 5, 9, 9, 9])
    pages, h, partial = idx.match(div)
    assert pages == [5] and partial == (6, 2)
    # First publisher wins: republishing the same chain keeps page 5.
    assert idx.publish(root, (0, 1, 2, 3), page=7) == h1
    assert idx.match(np.arange(9))[0] == [5, 6]
    # Reallocation invalidates whatever chain the page cached.
    idx.forget_pages([5])
    pages, h, partial = idx.match(np.arange(9))
    assert pages == [] and h == root
    assert len(idx) == 1  # page 6's entry survives (different chain head)


def test_ngram_proposer_uses_previous_occurrence():
    p = NgramProposer(max_n=3)
    p.extend([1, 2, 3, 4, 1, 2, 3])
    # The current suffix (1,2,3) must match its PREVIOUS occurrence, not
    # itself, and propose the continuation seen there.
    assert p.propose(2) == [4, 1]
    q = NgramProposer(max_n=3)
    q.extend([7, 8, 9])
    assert q.propose(3) == []  # nothing repeats: no draft


# ------------------------- prefix caching end-to-end -------------------------


def test_prefix_hit_skips_prefill_and_matches_cold_tokens():
    engine = _engine(_tiny_lm("paged", num_pages=25), max_len=32, slots=4)
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, 47, size=(20,))
    gw = ServingGateway(engine, prefill_chunk=8, seed=0)
    rid = gw.submit(prompt, sampling=SamplingParams(max_new_tokens=8))
    cold = gw.drain()[rid]
    chunks_cold = gw.scheduler.stats["prefill_chunks"]
    rid = gw.submit(prompt, sampling=SamplingParams(max_new_tokens=8))
    warm = gw.drain()[rid]
    assert warm.tokens == cold.tokens
    s = gw.scheduler.stats
    assert s["prefix_hits"] == 1 and s["prefix_misses"] == 1
    # 2 of the prompt's 2.5 pages are published and reused; only the tail
    # (4 tokens, one chunk) prefills the second time.
    assert s["prefill_tokens_skipped"] == 16
    assert s["prefill_chunks"] == chunks_cold + 1
    assert gw.scheduler.allocator.num_in_use == 0


def test_cow_divergence_forks_exactly_once_and_matches_unshared():
    """A prompt diverging mid-page from a cached prefix forks the donor
    page once (copy-on-write) and must produce exactly the tokens of an
    uncached run — even while the donor's publisher is still decoding on
    the shared pages."""
    engine = _engine(_tiny_lm("paged", num_pages=25), max_len=32, slots=4)
    rng = np.random.default_rng(1)
    base = rng.integers(1, 47, size=(20,))
    div = base.copy()
    div[12:] = rng.integers(1, 47, size=(8,))  # diverges inside page 2

    gw = ServingGateway(engine, prefill_chunk=8, seed=0)
    rid_a = gw.submit(base, sampling=SamplingParams(max_new_tokens=8))
    # A finishes and publishes pages 1-2 of its prompt.
    res_a = gw.drain()
    # B re-runs the base prompt (keeps the shared pages live) while C
    # diverges; C's fork must not disturb B's view of the shared pages.
    rid_b = gw.submit(base, sampling=SamplingParams(max_new_tokens=8))
    rid_c = gw.submit(div, sampling=SamplingParams(max_new_tokens=8))
    res = gw.drain()
    assert gw.scheduler.stats["cow_forks"] == 1
    assert res[rid_b].tokens == res_a[rid_a].tokens

    ref = ServingGateway(engine, prefill_chunk=8, seed=0,
                         prefix_caching=False, spec_k=0)
    rid = ref.submit(div, sampling=SamplingParams(max_new_tokens=8))
    ref_c = ref.drain()[rid]
    rid = ref.submit(base, sampling=SamplingParams(max_new_tokens=8))
    ref_a = ref.drain()[rid]
    assert res[rid_c].tokens == ref_c.tokens
    assert res_a[rid_a].tokens == ref_a.tokens
    assert gw.scheduler.allocator.num_in_use == 0


def test_evict_restore_sequence_holding_shared_prefix_pages():
    """Preempting a sequence that shares prefix pages decrefs (never
    frees) them: the co-sharer keeps decoding on intact pages, and the
    restored victim finishes with exactly the uncontended tokens."""
    engine = _engine(_tiny_lm("paged", num_pages=1 + 4, page=4),
                     max_len=16, slots=2)
    dense = _engine(_tiny_lm(), max_len=16, slots=2)
    rng = np.random.default_rng(2)
    shared = rng.integers(1, 47, size=(6,))
    other = rng.integers(1, 47, size=(6,))

    sched = Scheduler(engine, prefill_chunk=4, spec_k=0)
    sched.submit(ServeRequest(request_id=0, prompt=shared, max_new_tokens=8))
    while not any(s is not None and s.state == 2  # _RUNNING
                  for s in sched._slot_seq):
        sched.step()
    # B shares A's published prompt page (refcount 2) ...
    sched.submit(ServeRequest(request_id=1, prompt=shared, max_new_tokens=8,
                              arrival_time=0.1))
    sched.step()
    assert sched.stats["prefix_hits"] == 1
    # ... and the high-priority C forces an eviction under the tight pool.
    sched.submit(ServeRequest(request_id=2, prompt=other, max_new_tokens=8,
                              priority=1, arrival_time=0.2))
    while sched.step():
        pass
    assert sched.stats["preemptions"] > 0, "pool contention never triggered"
    for rid, prompt in ((0, shared), (1, shared), (2, other)):
        expect, _ = dense.generate(prompt[None, :], max_new_tokens=8)
        np.testing.assert_array_equal(
            np.asarray(sched.result(rid).tokens), expect[0],
            err_msg=f"request {rid} diverged after eviction under sharing")
    assert sched.allocator.num_in_use == 0


def test_deadline_expiry_of_one_sharer_leaves_other_pages_intact():
    """A sharer cancelled by its deadline releases only its own
    references: the surviving sharer's prefix pages stay mapped and its
    output is unchanged, and the drain-time leak check stays clean."""
    engine = _engine(_tiny_lm("paged", num_pages=25), max_len=32, slots=4)
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, 47, size=(17,))
    gw = ServingGateway(engine, prefill_chunk=8, seed=0, spec_k=0)
    rid = gw.submit(prompt, sampling=SamplingParams(max_new_tokens=12))
    ref = gw.drain()[rid]
    rid_a = gw.submit(prompt, sampling=SamplingParams(max_new_tokens=12))
    rid_b = gw.submit(prompt, sampling=SamplingParams(max_new_tokens=12),
                      deadline_s=0.15)
    sched = gw.scheduler
    # Step until B holds shared pages (admitted, prefix hit), then let its
    # deadline lapse mid-flight while A keeps decoding.
    while sched.stats["prefix_hits"] < 2:
        gw.step()
    time.sleep(0.2)
    res = gw.drain()
    assert res[rid_b].timed_out
    assert res[rid_a].tokens == ref.tokens
    assert sched.stats["timeouts"] == 1
    assert sched.allocator.num_in_use == 0


def test_gateway_drain_asserts_zero_page_references():
    engine = _engine(_tiny_lm("paged", num_pages=9), max_len=32, slots=2)
    gw = ServingGateway(engine, prefill_chunk=8)
    rid = gw.submit(np.asarray([1, 2, 3]),
                    sampling=SamplingParams(max_new_tokens=2))
    assert not gw.drain()[rid].timed_out
    # Simulate a refcount bug: a page acquired outside any sequence.
    gw.scheduler.allocator.alloc(1)
    with pytest.raises(RuntimeError, match="KV page leak after drain"):
        gw.drain()


# --------------------------- speculative decoding ----------------------------


@pytest.mark.parametrize("layout,backend", [
    ("dense", "ref"),
    ("paged", "ref"),
    ("paged", "pallas"),
])
def test_spec_decoding_matches_plain_greedy(layout, backend):
    """Draft-verify must be token-for-token identical to plain greedy
    decoding on every cache layout — including the interpreted Pallas
    paged-decode kernel, whose multi-token verify window resolves through
    the same registry path as chunked prefill."""
    num_pages = 25 if layout == "paged" else None
    engine = _engine(_tiny_lm(layout, num_pages=num_pages,
                              decode_backend=backend),
                     max_len=32, slots=2)
    rng = np.random.default_rng(4)
    prompts = [np.tile(np.asarray([5, 9, 3, 7]), 5),  # n-gram friendly
               rng.integers(1, 47, size=(11,))]
    spec = ServingGateway(engine, prefill_chunk=8, seed=0)
    plain = ServingGateway(engine, prefill_chunk=8, seed=0, spec_k=0)
    for prompt in prompts:
        rid = spec.submit(prompt, sampling=SamplingParams(max_new_tokens=8))
        a = spec.drain()[rid]
        rid = plain.submit(prompt, sampling=SamplingParams(max_new_tokens=8))
        b = plain.drain()[rid]
        assert a.tokens == b.tokens, f"spec diverged on {layout}/{backend}"
    assert spec.scheduler.stats["drafted_tokens"] > 0


def test_spec_accepts_multiple_tokens_and_mixed_batch_stays_exact():
    """A repetitive greedy prompt must accept > 1 token per verify step,
    and greedy rows riding the batched verify next to sampled rows must
    still reproduce plain greedy exactly (sampled rows ride at
    n_draft = 0; position-0 logits are unaffected by draft padding)."""
    engine = _engine(_tiny_lm("paged", num_pages=25), max_len=32, slots=4)
    rep = np.tile(np.asarray([5, 9, 3, 7]), 5)
    rng = np.random.default_rng(5)
    noisy = rng.integers(1, 47, size=(9,))

    gw = ServingGateway(engine, prefill_chunk=8, seed=0)
    rid_g = gw.submit(rep, sampling=SamplingParams(max_new_tokens=10))
    rid_s = gw.submit(noisy, sampling=SamplingParams(max_new_tokens=10,
                                                     temperature=0.8))
    res = gw.drain()
    s = gw.scheduler.stats
    assert s["verify_steps"] > 0
    # accepted_per_step = (accepted + verify) / verify > 1 needs at least
    # one accepted draft token; the repetitive prompt guarantees many.
    assert s["accepted_tokens"] >= s["verify_steps"]
    assert len(res[rid_s].tokens) == 10

    plain = ServingGateway(engine, prefill_chunk=8, seed=0, spec_k=0)
    rid = plain.submit(rep, sampling=SamplingParams(max_new_tokens=10))
    ref = plain.drain()[rid]
    assert res[rid_g].tokens == ref.tokens


def test_recurrent_state_disables_speculation_and_prefix():
    """Recurrent mixers consume tokens irreversibly — no KV positions to
    rewind — so the scheduler must gate drafting (and prefix sharing) off
    rather than corrupt state."""
    from repro.layers import CausalLM, Decoder, Repeat
    from repro.layers.rwkv import RWKV6Block

    block = RWKV6Block.default_config().set(input_dim=32)
    block.time_mix.set(head_dim=16, decay_lora_dim=8)
    block.time_mix.kernel.set(wkv_chunk_size=4)
    block.channel_mix.set(hidden_dim=64)
    model = CausalLM.default_config().set(
        name="lm",
        decoder=Decoder.default_config().set(
            vocab_size=48, dim=32,
            stack=Repeat.default_config().set(layer=block, num_layers=2,
                                              remat_policy=None)))
    engine = _engine(model, slots=2)
    sched = Scheduler(engine, prefill_chunk=4)
    assert sched.spec_k == 0 and sched.prefix is None
    rep = np.tile(np.asarray([5, 9, 3], np.int32), 4)
    res = sched.run([ServeRequest(request_id=0, prompt=rep,
                                  max_new_tokens=4)])
    expect, _ = engine.generate(rep[None, :], max_new_tokens=4)
    np.testing.assert_array_equal(np.asarray(res[0].tokens), expect[0])
    assert sched.stats["verify_steps"] == 0


def test_spec_verify_program_compiles_once():
    """The batched verify is one program per scheduler (K fixed): a
    second workload with new prompt lengths, drafts, and accept counts
    must not add a compile anywhere in the serving path."""
    engine = _engine(_tiny_lm("paged", num_pages=25), max_len=32, slots=4)
    gw = ServingGateway(engine, prefill_chunk=8, seed=0)
    rng = np.random.default_rng(6)

    def workload(seed_tile):
        gw.submit(np.tile(np.asarray(seed_tile), 6),
                  sampling=SamplingParams(max_new_tokens=8))
        gw.submit(rng.integers(1, 47, size=(int(rng.integers(4, 14)),)),
                  sampling=SamplingParams(max_new_tokens=6, temperature=0.7))
        gw.drain()

    # A 15-token prompt decomposes into chunks 8+4+2+1, warming every
    # prefill program any later (≤ 15-token) prompt can need.
    gw.submit(rng.integers(1, 47, size=(15,)),
              sampling=SamplingParams(max_new_tokens=2))
    workload([5, 9, 3])
    key = ("serve_spec_decode", gw.scheduler.spec_k)
    assert key in engine._jit_fns, "spec workload never hit the verify path"
    sizes = {k: fn._cache_size() for k, fn in engine._jit_fns.items()}
    assert sizes[key] == 1
    workload([8, 2, 4])
    after = {k: fn._cache_size() for k, fn in engine._jit_fns.items()}
    assert after == sizes, f"serving path recompiled: {sizes} -> {after}"


# ------------------------------ kernel features ------------------------------


def test_multi_query_feature_distinguishes_verify_windows():
    """S' > 1 decode calls (chunked prefill, speculative verify) resolve
    under a distinct feature key from 1-token decode steps."""
    one = KernelFeatures(platform=kreg.current_platform(), dtype="float32",
                         paged=True)
    multi = KernelFeatures(platform=kreg.current_platform(), dtype="float32",
                            paged=True, multi_query=True)
    assert one != multi and hash(one) != hash(multi)
    for feats in (one, multi):
        spec = kreg.resolve_backend("attention.decode", feats, DEFAULT_CONFIG)
        assert callable(spec.fn)
