"""Tests for MoE, Mamba, RWKV6: correctness, invariants, decode equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.module import functional
from repro.kernels import ref as kref
from repro.layers.moe import MoELayer, ResidualMoE, TopKRouter
from repro.layers.rwkv import RWKV6Block, RWKV6TimeMix
from repro.layers.ssm import MambaMixer


def run(cfg, inputs, *, state=None, method="forward", training=False, seed=0):
    layer = cfg.instantiate()
    if state is None:
        state = layer.initialize_parameters_recursively(jax.random.PRNGKey(seed))
    out, col = functional(layer, state=state, inputs=inputs, is_training=training,
                          prng_key=jax.random.PRNGKey(seed + 1), method=method)
    return layer, state, out, col


# ------------------------------- MoE ----------------------------------------


def _moe_cfg(E=4, k=2, d=16, h=32, cf=2.0):
    return MoELayer.default_config().set(
        name="moe", input_dim=d, hidden_dim=h, num_experts=E, top_k=k,
        capacity_factor=cf)


def test_moe_shapes_and_aux_loss_via_context():
    cfg = _moe_cfg()
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16))
    _, _, out, col = run(cfg, (x,))
    assert out.shape == x.shape
    assert jnp.isfinite(out).all()
    # Aux loss surfaced through the InvocationContext, not the return value.
    aux_keys = [k for k in col.module_outputs if k.endswith("aux_loss")]
    assert aux_keys == ["router/aux_loss"]
    assert jnp.isfinite(col.module_outputs[aux_keys[0]])


def test_moe_uniform_router_passes_tokens():
    """With capacity_factor high enough, (almost) no tokens drop: the combine
    of a token's top-k gates sums to ~1 when normalize_top_k=True."""
    cfg = _moe_cfg(E=4, k=2, cf=4.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    layer = cfg.instantiate()
    state = layer.initialize_parameters_recursively(jax.random.PRNGKey(0))
    (dispatch, combine), _ = functional(
        layer.router, state=state["router"], inputs={"x": x, "capacity": 16},
        method="forward")
    # dispatch entries are one-hot: each token to <= k slots
    per_token = dispatch.sum(axis=(2, 3))
    assert (per_token <= 2 + 1e-6).all()
    np.testing.assert_allclose(np.asarray(combine.sum(axis=(2, 3))),
                               np.ones((2, 16)), atol=1e-5)
    # No slot is used twice.
    per_slot = dispatch.sum(axis=1)
    assert (per_slot <= 1 + 1e-6).all()


@given(st.integers(2, 8), st.integers(1, 2), st.integers(4, 32),
       st.floats(0.5, 2.0))
@settings(max_examples=20, deadline=None)
def test_moe_capacity_invariants_property(E, k, S, cf):
    """Property: dispatched slots never exceed capacity; combine <= dispatch
    support; every dispatched token position is within capacity."""
    d = 8
    cfg = MoELayer.default_config().set(
        name="moe", input_dim=d, hidden_dim=16, num_experts=E, top_k=k,
        capacity_factor=cf)
    layer = cfg.instantiate()
    state = layer.initialize_parameters_recursively(jax.random.PRNGKey(0))
    C = layer._capacity(S)
    x = jax.random.normal(jax.random.PRNGKey(E * 31 + S), (1, S, d))
    (dispatch, combine), _ = functional(
        layer.router, state=state["router"], inputs={"x": x, "capacity": C},
        method="forward")
    per_slot = np.asarray(dispatch.sum(axis=1))  # (G,E,C)
    assert (per_slot <= 1 + 1e-6).all(), "slot collision"
    assert (np.asarray(combine) >= -1e-6).all()
    support = np.asarray(dispatch) > 0
    assert (np.asarray(combine)[~support] == 0).all(), "combine outside dispatch"


def test_moe_overflow_drops_tokens():
    cfg = _moe_cfg(E=2, k=1, cf=0.5)  # capacity ~ S/4
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, 16))
    _, _, out, col = run(cfg, (x,))
    frac = col.summaries["router/dispatched_fraction"]
    assert frac < 1.0, "should observe drops with tiny capacity"


def test_residual_moe_composition():
    cfg = ResidualMoE.default_config().set(name="rm", input_dim=16)
    cfg.dense.set(hidden_dim=32, activation=("linear", "nn.silu"))
    cfg.moe.set(hidden_dim=32, num_experts=4)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, 16))
    _, _, out, col = run(cfg, (x,))
    assert out.shape == x.shape
    assert any(k.endswith("aux_loss") for k in col.module_outputs)


# ------------------------------ Mamba ---------------------------------------


def _mamba_cfg(d=16):
    return MambaMixer.default_config().set(name="m", input_dim=d)


def test_mamba_forward_shape_and_finite():
    cfg = _mamba_cfg()
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 12, 16))
    _, _, out, _ = run(cfg, (x,))
    assert out.shape == x.shape and jnp.isfinite(out).all()


def test_mamba_associative_scan_matches_sequential():
    """Parallel prefix == naive recurrence."""
    cfg = _mamba_cfg()
    layer = cfg.instantiate()
    state = layer.initialize_parameters_recursively(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 10, 16))
    full, _ = functional(layer, state=state, inputs=(x,))
    # Sequential: decode token by token from fresh state.
    cache, _ = functional(layer, state=state, inputs=(1, 10), method="init_states")
    ys = []
    for t in range(10):
        (cache, y), _ = functional(layer, state=state,
                                   inputs={"state": cache, "x_step": x[:, t:t + 1]},
                                   method="extend_step")
        ys.append(y)
    seq = jnp.concatenate(ys, 1)
    # Log-depth parallel prefix reassociates the f32 products vs the naive
    # recurrence; observed max |diff| ~3e-3 on this seed.
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full), atol=5e-3)


def test_mamba_prefill_then_decode_matches_forward():
    cfg = _mamba_cfg()
    layer = cfg.instantiate()
    state = layer.initialize_parameters_recursively(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 12, 16))
    full, _ = functional(layer, state=state, inputs=(x,))
    cache, _ = functional(layer, state=state, inputs=(2, 12), method="init_states")
    (cache, y0), _ = functional(layer, state=state,
                                inputs={"state": cache, "x": x[:, :7]}, method="prefill")
    (cache, y1), _ = functional(layer, state=state,
                                inputs={"state": cache, "x_step": x[:, 7:]},
                                method="extend_step")
    # bf16 conv-ring state rounds at the prefill->decode boundary.
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y0, y1], 1)),
                               np.asarray(full), atol=5e-3)


# ------------------------------ RWKV6 ---------------------------------------


def test_wkv6_chunked_matches_recurrent():
    B, T, H, K, V = 2, 32, 2, 8, 8
    rng = jax.random.PRNGKey(8)
    ks = jax.random.split(rng, 5)
    r = jax.random.normal(ks[0], (B, T, H, K))
    k = jax.random.normal(ks[1], (B, T, H, K))
    v = jax.random.normal(ks[2], (B, T, H, V))
    w = jax.random.uniform(ks[3], (B, T, H, K), minval=0.5, maxval=0.99)
    u = jax.random.normal(ks[4], (H, K)) * 0.5
    out_seq, s_seq = kref.reference_wkv6_recurrent(r, k, v, w, u)
    out_chk, s_chk = kref.reference_wkv6(r, k, v, w, u, chunk_size=8)
    np.testing.assert_allclose(np.asarray(out_chk), np.asarray(out_seq),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_seq),
                               rtol=1e-4, atol=1e-4)


def test_wkv6_chunked_with_initial_state():
    B, T, H, K, V = 1, 16, 1, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(9), 6)
    r, k = (jax.random.normal(ks[i], (B, T, H, K)) for i in range(2))
    v = jax.random.normal(ks[2], (B, T, H, V))
    w = jax.random.uniform(ks[3], (B, T, H, K), minval=0.6, maxval=0.98)
    u = jax.random.normal(ks[4], (H, K)) * 0.5
    s0 = jax.random.normal(ks[5], (B, H, K, V)).astype(jnp.float32)
    out_a, sa = kref.reference_wkv6_recurrent(r, k, v, w, u, s0)
    out_b, sb = kref.reference_wkv6(r, k, v, w, u, s0, chunk_size=4)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_a), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sb), np.asarray(sa), rtol=1e-4, atol=1e-4)


def test_rwkv_block_decode_matches_forward():
    cfg = RWKV6Block.default_config().set(name="b", input_dim=32)
    cfg.time_mix.set(head_dim=16, decay_lora_dim=8)
    cfg.time_mix.kernel.set(wkv_chunk_size=4)
    cfg.channel_mix.set(hidden_dim=64)
    layer = cfg.instantiate()
    state = layer.initialize_parameters_recursively(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(10), (2, 8, 32)) * 0.1
    full, _ = functional(layer, state=state, inputs=(x,))
    cache, _ = functional(layer, state=state, inputs=(2, 8), method="init_states")
    (cache, y0), _ = functional(layer, state=state,
                                inputs={"state": cache, "x": x[:, :4]}, method="prefill")
    ys = [y0]
    for t in range(4, 8):
        (cache, y), _ = functional(layer, state=state,
                                   inputs={"state": cache, "x_step": x[:, t:t + 1]},
                                   method="extend_step")
        ys.append(y)
    # bf16 token-shift state rounds at chunk boundaries.
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(full), atol=2e-2)


def test_moe_drop_in_replacement_via_replace_config():
    """THE paper demo: integrate MoE into an existing transformer experiment
    with a ~5-line traversal; zero changes to any layer/model code."""
    from repro.core.config import replace_config
    from repro.layers import FeedForward, Repeat, TransformerLayer

    layer_cfg = TransformerLayer.default_config().set(name="t", input_dim=32)
    layer_cfg.self_attention.set(num_heads=4)
    layer_cfg.feed_forward.set(hidden_dim=64)
    stack = Repeat.default_config().set(
        name="s", layer=layer_cfg, num_layers=2, remat_policy=None)

    # --- the integration snippet (what the paper counts as ~10 LoC) --------
    n = replace_config(
        stack,
        target=FeedForward,
        new_cfg=MoELayer.default_config().set(num_experts=4, top_k=2),
        propagate=("input_dim", "hidden_dim"),
    )
    # ------------------------------------------------------------------------
    assert n == 1
    rep = stack.instantiate()
    state = rep.initialize_parameters_recursively(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(11), (2, 8, 32))
    out, col = functional(rep, state=state, inputs=(x,), is_training=True,
                          prng_key=jax.random.PRNGKey(1))
    assert out.shape == x.shape
    # Aux losses flow up through the scan boundary, stacked per layer.
    aux = [v for k, v in col.module_outputs.items() if k.endswith("aux_loss")]
    assert len(aux) == 1 and aux[0].shape == (2,)  # (num_layers,)
