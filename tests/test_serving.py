"""Paged-KV serving subsystem: allocator invariants, paged kernel parity,
chunked prefill, preemption/eviction, streaming gateway, and the
2x-concurrency acceptance criterion.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.inference.engine import InferenceEngine, Request
from repro.kernels import ops, ref
from repro.layers import CausalLM, Decoder, Repeat, TransformerLayer
from repro.kernels.registry import KernelConfig
from repro.serving import (
    BlockAllocator,
    SamplingParams,
    Scheduler,
    ServeRequest,
    ServingGateway,
)


def _tiny_lm(layout="dense", num_pages=None, page=8, decode_backend="ref",
             vocab=48, dim=32):
    layer = TransformerLayer.default_config().set(input_dim=dim)
    # "pallas" runs interpreted on CPU via the registry (pallas:interpret).
    kernel = KernelConfig().set(
        op_overrides={"attention.decode": decode_backend},
        interpret=(decode_backend == "pallas"))
    layer.self_attention.set(num_heads=4, num_kv_heads=2, kernel=kernel,
                             kv_cache_dtype=jnp.float32,
                             kv_cache_layout=layout, page_size=page,
                             num_pages=num_pages)
    layer.feed_forward.set(hidden_dim=dim * 2)
    return CausalLM.default_config().set(
        name="lm",
        decoder=Decoder.default_config().set(
            vocab_size=vocab, dim=dim,
            stack=Repeat.default_config().set(layer=layer, num_layers=2,
                                              remat_policy=None)))


def _engine(model_cfg, max_len=32, slots=4):
    cfg = InferenceEngine.default_config().set(
        name="engine", model=model_cfg, max_len=max_len, slots=slots)
    engine = cfg.instantiate()
    params = engine.model.initialize_parameters_recursively(jax.random.PRNGKey(0))
    engine.load(params)
    return engine


# ------------------------------- allocator -----------------------------------


def test_allocator_basics():
    a = BlockAllocator(8)  # 7 usable, page 0 reserved
    assert a.capacity == 7
    pages = a.alloc(3)
    assert len(pages) == 3 and 0 not in pages
    assert a.num_free == 4 and a.num_in_use == 3
    assert a.alloc(5) is None  # insufficient: None, not an exception
    a.free(pages)
    assert a.num_free == 7 and a.num_in_use == 0


def test_allocator_double_free_raises():
    a = BlockAllocator(4)
    pages = a.alloc(2)
    a.free(pages)
    with pytest.raises(ValueError, match="unallocated"):
        a.free(pages)
    with pytest.raises(ValueError, match="unallocated"):
        a.free([0])  # the null page was never handed out


def test_allocator_churn_never_leaks_or_double_allocates():
    """Randomized alloc/free churn: live pages stay disjoint, page 0 never
    appears, and after freeing everything the pool is whole again."""
    rng = np.random.default_rng(0)
    a = BlockAllocator(33)
    live = []
    for _ in range(500):
        if live and rng.random() < 0.45:
            i = int(rng.integers(len(live)))
            a.free(live.pop(i))
        else:
            got = a.alloc(int(rng.integers(1, 5)))
            if got is not None:
                live.append(got)
        flat = [p for pages in live for p in pages]
        assert len(flat) == len(set(flat)), "page double-allocated"
        assert 0 not in flat, "null page allocated"
        assert a.num_in_use + a.num_free == a.capacity, "pages leaked"
    for pages in live:
        a.free(pages)
    assert a.num_free == a.capacity and a.num_in_use == 0


# --------------------------- paged kernel parity -----------------------------


def _paged_fixture():
    B, Hq, Hkv, D = 2, 4, 2, 16
    P, page = 7, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    k_pool = jax.random.normal(ks[1], (P, page, Hkv, D))
    v_pool = jax.random.normal(ks[2], (P, page, Hkv, D))
    # seq 0: pages [3, 1] holding 13 tokens; seq 1: page [5] holding 4.
    tbl = jnp.asarray([[3, 1, -1], [5, -1, -1]], jnp.int32)
    pos_pool = jnp.full((P, page), -1, jnp.int32)
    pos_pool = pos_pool.at[3].set(jnp.arange(8)).at[1, :5].set(jnp.arange(8, 13))
    pos_pool = pos_pool.at[5, :4].set(jnp.arange(4))
    return ks[0], k_pool, v_pool, pos_pool, tbl


@pytest.mark.parametrize("Sq", [1, 3])
def test_paged_flash_decode_matches_gathered_reference(Sq):
    """The scalar-prefetch paged kernel == XLA-gather + reference oracle,
    for single- and multi-step (chunked prefill shaped) queries."""
    qkey, k_pool, v_pool, pos_pool, tbl = _paged_fixture()
    q = jax.random.normal(qkey, (2, Sq, 4, 16))
    q_pos = jnp.asarray([[13 + i for i in range(Sq)],
                         [4 + i for i in range(Sq)]], jnp.int32)
    out = ops.decode_attention(q, k_pool, v_pool, q_positions=q_pos,
                               k_positions=pos_pool, page_tables=tbl,
                               kernel=KernelConfig().set(interpret=True))
    kg, vg, kposg = ops.paged_gather_kv(k_pool, v_pool, pos_pool, tbl)
    expect = ref.reference_attention(q, kg, vg, q_positions=q_pos,
                                     k_positions=kposg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


def test_paged_flash_decode_fully_unmapped_sequence_is_finite():
    """A sequence whose table is all -1 (fresh slot) must produce zeros, not
    NaN — unmapped pages are masked via the table, not page contents."""
    qkey, k_pool, v_pool, pos_pool, tbl = _paged_fixture()
    q = jax.random.normal(qkey, (2, 1, 4, 16))
    tbl = tbl.at[1].set(-1)
    out = ops.decode_attention(q, k_pool, v_pool,
                               q_positions=jnp.asarray([[13], [0]]),
                               k_positions=pos_pool, page_tables=tbl,
                               kernel=KernelConfig().set(interpret=True))
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out[1]), 0.0, atol=1e-6)


# --------------------------- paged layer / engine ----------------------------


@pytest.mark.parametrize("decode_backend", ["ref", "pallas"])
def test_paged_generate_matches_dense(decode_backend):
    """kv_cache_layout is semantics-free: full-residency paged generation
    (identity page tables) == dense generation, for both decode impls."""
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 48))
    t_dense, _ = _engine(_tiny_lm()).generate(prompts, max_new_tokens=6)
    t_paged, _ = _engine(_tiny_lm("paged", decode_backend=decode_backend)).generate(
        prompts, max_new_tokens=6)
    np.testing.assert_array_equal(t_dense, t_paged)


def test_paged_rejects_sliding_window():
    cfg = _tiny_lm("paged")
    cfg.decoder.stack.layer.self_attention.set(sliding_window=8)
    with pytest.raises(ValueError, match="sliding_window"):
        _engine(cfg)


def test_scheduler_requires_explicit_num_pages_for_paged():
    engine = _engine(_tiny_lm("paged"))  # num_pages=None: full residency
    with pytest.raises(ValueError, match="num_pages"):
        Scheduler(engine)


def test_scheduler_rejects_prompt_beyond_capacity():
    engine = _engine(_tiny_lm("paged", num_pages=1 + 4, page=4))  # 16 tokens
    sched = Scheduler(engine)
    with pytest.raises(ValueError, match="exceeds paged KV capacity"):
        sched.submit(ServeRequest(request_id=0,
                                  prompt=np.zeros(20, np.int32)))


def test_scheduler_rejects_empty_prompt():
    engine = _engine(_tiny_lm("paged", num_pages=1 + 8))
    sched = Scheduler(engine)
    with pytest.raises(ValueError, match="empty prompt"):
        sched.submit(ServeRequest(request_id=0,
                                  prompt=np.zeros(0, np.int32)))


def test_scheduler_capacity_bounded_by_table_width():
    """A pool larger than one page-table row must not let a sequence index
    past its table: generation truncates at n_logical * page_size instead
    of crashing."""
    # max_len=16, page=4 -> 4 table rows (16 tokens/seq); pool of 11 usable
    # pages (44 tokens) exceeds one row on purpose.
    engine = _engine(_tiny_lm("paged", num_pages=12, page=4),
                     max_len=16, slots=2)
    sched = Scheduler(engine, prefill_chunk=8)
    assert sched.capacity_tokens == 16
    rng = np.random.default_rng(11)
    res = sched.run([ServeRequest(request_id=0,
                                  prompt=rng.integers(0, 48, size=(10,)),
                                  max_new_tokens=20)])
    # 10 prompt + 6 generated fill the 16-token table; truncated, not crashed.
    assert len(res[0].tokens) <= 7 and sched.stats["truncated"] == 1


def test_generate_rejects_underprovisioned_paged_pool():
    """generate() needs full-residency identity tables; a serving-sized
    pool must fail loudly, not silently drop every KV write."""
    engine = _engine(_tiny_lm("paged", num_pages=12), max_len=32, slots=4)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0, 48))
    with pytest.raises(ValueError, match="below full residency"):
        engine.generate(prompts, max_new_tokens=4)


# ------------------------------ chunked prefill ------------------------------


@pytest.mark.parametrize("chunk", [4, 16])
def test_chunked_prefill_matches_unchunked(chunk):
    """Token streams are identical whether a prompt is prefilled whole
    (generate) or in power-of-two chunks through the scheduler."""
    engine = _engine(_tiny_lm("paged", num_pages=1 + 16), slots=4)
    dense = _engine(_tiny_lm())
    rng = np.random.default_rng(0)
    lens = [5, 9, 16, 3, 12]
    prompts = [rng.integers(0, 48, size=(n,)) for n in lens]
    sched = Scheduler(engine, prefill_chunk=chunk)
    res = sched.run([ServeRequest(request_id=i, prompt=p, max_new_tokens=4)
                     for i, p in enumerate(prompts)])
    for i, r in enumerate(res):
        expect, _ = dense.generate(prompts[i][None, :], max_new_tokens=4)
        np.testing.assert_array_equal(np.asarray(r.tokens), expect[0])
    # Compiled chunk programs stay within the power-of-two decomposition.
    chunk_sizes = [k[1] for k in engine._jit_fns
                   if isinstance(k, tuple) and k[0] == "serve_chunk"]
    assert chunk_sizes and max(chunk_sizes) <= chunk


def test_chunked_prefill_interleaves_with_decode():
    """A long prompt must not stall in-flight decodes: the short request
    streams tokens between the long prompt's chunks (per-iteration stall
    bounded by the chunk budget)."""
    engine = _engine(_tiny_lm("paged", num_pages=1 + 16), slots=2)
    sched = Scheduler(engine, prefill_chunk=4)
    rng = np.random.default_rng(1)
    order = []
    # The short request is decoding when the long prompt arrives: its
    # 16-token prefill takes 4 chunked iterations, each of which also runs
    # a decode step for the short request.
    short_req = ServeRequest(request_id=1, prompt=rng.integers(0, 48, size=(2,)),
                             max_new_tokens=6, arrival_time=0.0,
                             on_token=lambda rid, t: order.append(rid))
    long_req = ServeRequest(request_id=0, prompt=rng.integers(0, 48, size=(16,)),
                            max_new_tokens=2, arrival_time=0.1,
                            on_token=lambda rid, t: order.append(rid))
    sched.submit(short_req)
    sched.submit(long_req)
    while sched.step():
        pass
    # Several short-request tokens must land BEFORE the long prompt's first
    # token — iteration-level interleaving, not run-to-completion.
    first_long = order.index(0)
    assert order[:first_long].count(1) >= 3, \
        f"decode stalled behind prefill: {order}"


def test_chunked_prefill_recurrent_mixer_matches_generate():
    """Recurrent mixers bypass paging (O(1) state) but share the chunked
    prefill path; chunk boundaries must be invisible to the state."""
    from repro.layers.rwkv import RWKV6Block

    block = RWKV6Block.default_config().set(input_dim=32)
    block.time_mix.set(head_dim=16, decay_lora_dim=8)
    block.time_mix.kernel.set(wkv_chunk_size=4)
    block.channel_mix.set(hidden_dim=64)
    model = CausalLM.default_config().set(
        name="lm",
        decoder=Decoder.default_config().set(
            vocab_size=48, dim=32,
            stack=Repeat.default_config().set(layer=block, num_layers=2,
                                              remat_policy=None)))
    engine = _engine(model, slots=2)
    sched = Scheduler(engine, prefill_chunk=4)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 48, size=(n,)) for n in (6, 11, 3)]
    res = sched.run([ServeRequest(request_id=i, prompt=p, max_new_tokens=4)
                     for i, p in enumerate(prompts)])
    for i, r in enumerate(res):
        expect, _ = engine.generate(prompts[i][None, :], max_new_tokens=4)
        np.testing.assert_array_equal(np.asarray(r.tokens), expect[0])


# --------------------------- eviction / preemption ---------------------------


def test_evict_restore_roundtrip_exact():
    """When pages run out, the lowest-priority sequence is evicted to host
    and later restored by re-splicing pages — its token stream must be
    byte-identical to an uncontended run."""
    engine = _engine(_tiny_lm("paged", num_pages=1 + 4, page=4),
                     max_len=16, slots=2)
    dense = _engine(_tiny_lm(), max_len=16, slots=2)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 48, size=(6,)) for _ in range(2)]
    sched = Scheduler(engine, prefill_chunk=4)
    res = sched.run([
        ServeRequest(request_id=0, prompt=prompts[0], max_new_tokens=8,
                     priority=0),
        ServeRequest(request_id=1, prompt=prompts[1], max_new_tokens=8,
                     priority=1),
    ])
    assert sched.stats["preemptions"] > 0, "pool contention never triggered"
    assert sched.stats["restores"] == sched.stats["preemptions"]
    for i, r in enumerate(res):
        expect, _ = dense.generate(prompts[i][None, :], max_new_tokens=8)
        np.testing.assert_array_equal(np.asarray(r.tokens), expect[0])


def test_scheduler_never_leaks_pages_under_churn():
    """Allocator invariants after a contended mixed workload: all pages
    returned, and recycled pages never leak a previous tenant's tokens.

    Freed pages now intentionally KEEP their contents (the prefix index
    may revive them for cache hits; positions reset lazily at the next
    allocation), so instead of asserting pos_pool == -1 we assert the
    stronger end-to-end property the reset exists for: a second request
    wave through the same (dirty) scheduler decodes exactly."""
    engine = _engine(_tiny_lm("paged", num_pages=1 + 6, page=4),
                     max_len=24, slots=3)
    dense = _engine(_tiny_lm(), max_len=24, slots=3)
    sched = Scheduler(engine, prefill_chunk=4)
    rng = np.random.default_rng(2)
    reqs = [ServeRequest(request_id=i,
                         prompt=rng.integers(0, 48, size=(int(rng.integers(2, 14)),)),
                         max_new_tokens=int(rng.integers(1, 8)),
                         priority=int(rng.integers(0, 3)))
            for i in range(10)]
    res = sched.run(reqs)
    assert len(res) == 10 and all(r.tokens for r in res)
    assert sched.allocator.num_in_use == 0, "pages leaked"
    assert sched.allocator.num_free == sched.allocator.capacity
    wave2 = [ServeRequest(request_id=100 + i,
                          prompt=rng.integers(0, 48, size=(7,)),
                          max_new_tokens=6)
             for i in range(3)]
    res2 = sched.run(wave2)
    for r, req in zip(res2, wave2):
        expect, _ = dense.generate(req.prompt[None, :], max_new_tokens=6)
        np.testing.assert_array_equal(np.asarray(r.tokens),
                                      expect[0][:len(r.tokens)])
    assert sched.allocator.num_in_use == 0, "pages leaked"


# ----------------------------- 2x concurrency --------------------------------


def _kv_bytes(engine):
    cache = engine.init_cache()
    flat = jax.tree_util.tree_flatten_with_path(cache)[0]
    total = 0
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        if any(s in name for s in ("'k'", "'v'", "k_pool", "v_pool")):
            total += leaf.size * leaf.dtype.itemsize
    return total


def test_paged_serves_2x_concurrent_sequences_at_equal_memory():
    """The acceptance criterion: with the SAME KV byte budget as a dense
    4-slot engine, the paged engine keeps 8 sequences device-resident
    simultaneously (each using < max_len) and serves them exactly."""
    dense = _engine(_tiny_lm(), max_len=32, slots=4)
    # Dense budget: 4 slots x 32 tokens = 128 token-slots per layer.
    # Paged: 16 pages x 8 tokens = 128 (15 usable + null) on 8 slots.
    paged = _engine(_tiny_lm("paged", num_pages=16, page=8),
                    max_len=32, slots=8)
    assert _kv_bytes(paged) <= _kv_bytes(dense), "paged pool exceeds budget"

    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 48, size=(8,)) for _ in range(8)]
    sched = Scheduler(paged, prefill_chunk=8)
    res = sched.run([ServeRequest(request_id=i, prompt=p, max_new_tokens=6)
                     for i, p in enumerate(prompts)])
    assert sched.stats["max_concurrent"] == 8, (
        f"expected 8 device-resident sequences, got "
        f"{sched.stats['max_concurrent']}")
    assert sched.stats["preemptions"] == 0  # they genuinely fit
    for i, r in enumerate(res):
        expect, _ = dense.generate(prompts[i][None, :], max_new_tokens=6)
        np.testing.assert_array_equal(np.asarray(r.tokens), expect[0])


# ------------------------------- gateway -------------------------------------


def test_gateway_stream_matches_generate_greedy():
    """Streamed tokens == generate() output token-for-token under greedy."""
    engine = _engine(_tiny_lm("paged", num_pages=1 + 16), slots=4)
    dense = _engine(_tiny_lm())
    gw = ServingGateway(engine, prefill_chunk=4)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, 48, size=(n,)) for n in (5, 9, 3)]
    rids = [gw.submit(p, sampling=SamplingParams(max_new_tokens=5))
            for p in prompts]
    streamed = list(gw.stream(rids[0]))
    expect, _ = dense.generate(prompts[0][None, :], max_new_tokens=5)
    np.testing.assert_array_equal(np.asarray(streamed), expect[0])
    results = gw.drain()
    for rid, p in zip(rids[1:], prompts[1:]):
        expect, _ = dense.generate(p[None, :], max_new_tokens=5)
        np.testing.assert_array_equal(np.asarray(results[rid].tokens),
                                      expect[0])


def test_gateway_callbacks_and_metrics():
    engine = _engine(_tiny_lm("paged", num_pages=1 + 16), slots=2)
    gw = ServingGateway(engine, prefill_chunk=4)
    rng = np.random.default_rng(5)
    seen = []
    rid = gw.submit(rng.integers(0, 48, size=(6,)),
                    sampling=SamplingParams(max_new_tokens=4),
                    on_token=lambda r, t: seen.append((r, t)))
    results = gw.drain()
    assert [t for _, t in seen] == results[rid].tokens
    assert all(r == rid for r, _ in seen)
    m = gw.metrics()
    assert m["completed"] == 1 and m["queue_depth"] == 0
    assert m["tokens_out"] == 4 and m["tokens_per_s"] > 0
    assert m["ttft_p50_s"] > 0 and m["tpot_p50_s"] > 0
    assert 0.0 <= m["block_utilization"] <= 1.0
    assert results[rid].ttft_s > 0 and results[rid].tpot_s > 0


def test_gateway_per_request_sampling():
    """Greedy and sampled requests batch together; greedy rows stay exact."""
    engine = _engine(_tiny_lm("paged", num_pages=1 + 16), slots=4)
    dense = _engine(_tiny_lm())
    gw = ServingGateway(engine, prefill_chunk=8, seed=7)
    rng = np.random.default_rng(6)
    p_greedy = rng.integers(0, 48, size=(8,))
    p_sampled = rng.integers(0, 48, size=(8,))
    rid_g = gw.submit(p_greedy, sampling=SamplingParams(max_new_tokens=5))
    rid_s = gw.submit(p_sampled, sampling=SamplingParams(
        max_new_tokens=5, temperature=0.9, top_k=8))
    results = gw.drain()
    expect, _ = dense.generate(p_greedy[None, :], max_new_tokens=5)
    np.testing.assert_array_equal(np.asarray(results[rid_g].tokens), expect[0])
    assert len(results[rid_s].tokens) == 5
    assert all(0 <= t < 48 for t in results[rid_s].tokens)


# --------------------------- engine serve satellites -------------------------


def test_serve_per_slot_sampling_greedy_rows_exact():
    """Mixed greedy/sampled requests in one dense serve batch: greedy rows
    (and top_k=1 rows at any temperature) match generate exactly."""
    engine = _engine(_tiny_lm(), slots=4)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, 48, size=(8,)) for _ in range(4)]
    reqs = [
        Request(request_id=0, prompt=prompts[0], max_new_tokens=5),
        Request(request_id=1, prompt=prompts[1], max_new_tokens=5,
                temperature=0.9),
        Request(request_id=2, prompt=prompts[2], max_new_tokens=5,
                temperature=0.9, top_k=1),
        Request(request_id=3, prompt=prompts[3], max_new_tokens=5),
    ]
    res = engine.serve(reqs)
    for i in (0, 2, 3):  # greedy + top_k=1
        expect, _ = engine.generate(prompts[i][None, :], max_new_tokens=5)
        np.testing.assert_array_equal(np.asarray(res[i].tokens), expect[0])
    assert len(res[1].tokens) == 5
    assert all(0 <= t < 48 for t in res[1].tokens)


def test_serve_first_token_completion_sets_tpot():
    """A request finishing at its first token reports tpot_s = ttft_s (the
    prefill was the whole per-token cost), not a dangling 0.0."""
    engine = _engine(_tiny_lm(), slots=2)
    rng = np.random.default_rng(8)
    res = engine.serve([Request(request_id=0,
                                prompt=rng.integers(0, 48, size=(6,)),
                                max_new_tokens=1)])
    assert len(res[0].tokens) == 1
    assert res[0].tpot_s == pytest.approx(res[0].ttft_s) and res[0].tpot_s > 0


def test_serve_fcfs_is_stable():
    """Equal arrival times keep request order (sort key includes
    request_id) — and every request still gets its own prompt's tokens."""
    engine = _engine(_tiny_lm(), slots=1)
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, 48, size=(6,)) for _ in range(3)]
    reqs = [Request(request_id=i, prompt=prompts[i], max_new_tokens=3,
                    arrival_time=0.0) for i in (2, 0, 1)]
    res = engine.serve(reqs)
    for req, r in zip(reqs, res):
        assert r.request_id == req.request_id
        expect, _ = engine.generate(
            prompts[req.request_id][None, :], max_new_tokens=3)
        np.testing.assert_array_equal(np.asarray(r.tokens), expect[0])


# --------------------------- compile-count guard -----------------------------


def test_serving_path_compile_count_bounded():
    """Steady-state guard: after a warm-up workload, a second mixed workload
    (new lengths/slots/priorities within the same chunk budget) must not
    trigger a single new compile anywhere in the serving path."""
    engine = _engine(_tiny_lm("paged", num_pages=1 + 16), slots=4)
    sched = Scheduler(engine, prefill_chunk=8)
    rng = np.random.default_rng(10)

    def workload(n0, n):
        return [ServeRequest(request_id=n0 + i,
                             prompt=rng.integers(0, 48, size=(int(rng.integers(1, 15)),)),
                             max_new_tokens=int(rng.integers(1, 6)),
                             temperature=float(rng.random() < 0.5) * 0.8,
                             priority=int(rng.integers(0, 2)))
                for i in range(n)]

    # Warm-up includes a repetitive greedy prompt so the speculative
    # verify program compiles here — random workloads may not draft.
    warm = workload(0, 8) + [
        ServeRequest(request_id=50, prompt=np.tile([5, 9, 3], 6),
                     max_new_tokens=6)]
    sched.run(warm)
    compiles = {k: fn._cache_size() for k, fn in engine._jit_fns.items()}
    sched.run(workload(100, 8))
    after = {k: fn._cache_size() for k, fn in engine._jit_fns.items()}
    assert after == compiles, f"serving path recompiled: {compiles} -> {after}"
    # Chunk programs are bounded by the power-of-two decomposition.
    n_chunk_fns = sum(1 for k in engine._jit_fns
                      if isinstance(k, tuple) and k[0] == "serve_chunk")
    assert n_chunk_fns <= 4  # chunks of 8, 4, 2, 1


# ------------------------------ deadlines ------------------------------------


def test_deadline_expired_request_returns_timed_out_without_hanging():
    """A queued request whose deadline has already passed is cancelled at
    the next iteration: drain() resolves it (timed_out, no tokens) instead
    of serving — or hanging on — it, and the co-submitted request is
    unaffected."""
    engine = _engine(_tiny_lm("paged", num_pages=1 + 16), slots=2)
    gw = ServingGateway(engine, prefill_chunk=4)
    rng = np.random.default_rng(7)
    rid_ok = gw.submit(rng.integers(0, 48, size=(6,)),
                       sampling=SamplingParams(max_new_tokens=4))
    rid_dead = gw.submit(rng.integers(0, 48, size=(6,)),
                         sampling=SamplingParams(max_new_tokens=4),
                         deadline_s=0.0)
    results = gw.drain()
    assert results[rid_dead].timed_out
    assert results[rid_dead].tokens == []
    assert not results[rid_ok].timed_out
    assert len(results[rid_ok].tokens) == 4
    m = gw.metrics()
    assert m["timeouts"] == 1 and m["completed"] == 1
    # Timed-out requests don't pollute the latency percentiles.
    assert m["ttft_p50_s"] > 0


def test_stream_terminates_on_deadline():
    engine = _engine(_tiny_lm("paged", num_pages=1 + 16), slots=2)
    gw = ServingGateway(engine, prefill_chunk=4)
    rid = gw.submit(np.arange(2, 8), sampling=SamplingParams(max_new_tokens=8),
                    deadline_s=0.0)
    assert list(gw.stream(rid)) == []
    res = gw.result(rid)
    assert res is not None and res.timed_out


def test_deadline_mid_decode_frees_pages_and_keeps_partial_tokens():
    """A request cancelled mid-decode frees its pages and slot through the
    normal teardown path; the result keeps the tokens generated so far."""
    engine = _engine(_tiny_lm("paged", num_pages=1 + 8, page=4),
                     max_len=16, slots=2)
    sched = Scheduler(engine, prefill_chunk=4)
    rng = np.random.default_rng(8)
    sched.submit(ServeRequest(request_id=0,
                              prompt=rng.integers(0, 48, size=(4,)),
                              max_new_tokens=12, deadline_s=60.0))
    seq = None
    for _ in range(50):
        sched.step()
        seq = sched._done.get(0) or next(
            (s for s in sched._slot_seq if s is not None), None)
        if seq is not None and len(seq.tokens) >= 2:
            break
    assert seq is not None and len(seq.tokens) >= 2
    assert not sched.is_done(0)
    assert sched.allocator.num_in_use > 0
    seq.t_submit -= 120.0  # the deadline passes "now"
    sched.step()
    assert sched.is_done(0)
    res = sched.result(0)
    assert res.timed_out and len(res.tokens) >= 2
    assert sched.stats["timeouts"] == 1
    assert sched.allocator.num_in_use == 0
    assert all(s is None for s in sched._slot_seq)
    assert not sched.has_work


def test_deadline_on_preempted_sequence_no_double_free():
    """Expiring a sequence that sits EVICTED (pages already freed, host
    payload pending restore) must not free pages twice nor corrupt the
    allocator; the surviving request completes normally."""
    engine = _engine(_tiny_lm("paged", num_pages=1 + 4, page=4),
                     max_len=16, slots=2)
    sched = Scheduler(engine, prefill_chunk=4)
    rng = np.random.default_rng(9)
    p_low = rng.integers(0, 48, size=(6,))
    p_high = rng.integers(0, 48, size=(6,))
    sched.submit(ServeRequest(request_id=0, prompt=p_low, max_new_tokens=8,
                              priority=0, deadline_s=60.0))
    sched.submit(ServeRequest(request_id=1, prompt=p_high, max_new_tokens=8,
                              priority=1))
    victim = None
    for _ in range(200):
        if sched._preempted:
            victim = sched._preempted[0]
            break
        sched.step()
    assert victim is not None, "pool contention never evicted the low-prio"
    assert victim.req.request_id == 0
    victim.t_submit -= 120.0
    while sched.step():
        pass
    res0, res1 = sched.result(0), sched.result(1)
    assert res0.timed_out
    assert not res1.timed_out and len(res1.tokens) == 8
    assert sched.allocator.num_in_use == 0
    assert sched.allocator.num_free == sched.allocator.capacity
