"""Launch/analysis machinery tests (no heavy compiles — the real dry-run
artifacts live in experiments/dryrun; these validate the components)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core.utils import resolve_spec, set_mesh
from repro.launch.analysis import (
    _type_bytes,
    parse_collectives_dedup,
    roofline_terms,
)

HLO_SAMPLE = """
  %ag = bf16[16,1024]{1,0} all-gather(%p0), replica_groups={...}
  %ar.1 = f32[32,32]{1,0} all-reduce(%x), to_apply=%add
  %ars = f32[32,32]{1,0} all-reduce-start(%x), to_apply=%add
  %ard = f32[32,32]{1,0} all-reduce-done(%ars)
  %a2a = (bf16[8,8]{1,0}, bf16[8,8]{1,0}) all-to-all(%y, %z)
  %cp = u32[4]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %rs = f32[16]{0} reduce-scatter(%v), to_apply=%add
"""


def test_type_bytes():
    assert _type_bytes("bf16[16,1024]{1,0}") == 16 * 1024 * 2
    assert _type_bytes("f32[]") == 4  # scalar
    assert _type_bytes("(bf16[8,8], bf16[8,8])") == 2 * 64 * 2


def test_parse_collectives_dedup():
    out = parse_collectives_dedup(HLO_SAMPLE)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 16 * 1024 * 2
    # start counted once, done skipped, plain one counted.
    assert out["all-reduce"]["count"] == 2
    assert out["all-to-all"]["bytes"] == 2 * 64 * 2
    assert out["collective-permute"]["count"] == 1
    assert out["reduce-scatter"]["count"] == 1


def test_roofline_terms_dominance():
    rep = roofline_terms(
        cost={"flops": 197e12, "bytes accessed": 819e9 * 2},
        hlo_text=HLO_SAMPLE, chips=256, model_flops_global=197e12 * 256)
    assert rep.compute_s == pytest.approx(1.0)
    assert rep.memory_s == pytest.approx(2.0)
    assert rep.dominant == "memory"
    assert rep.useful_flops_ratio == pytest.approx(1.0)


def test_extrapolate_affine():
    from repro.launch.dryrun import extrapolate_affine

    # cost(L) = 10 (outside) + 3 per layer -> c1 = 13, c2 = 16.
    assert extrapolate_affine(13.0, 16.0, 28) == pytest.approx(10 + 3 * 28)
    assert extrapolate_affine(5.0, 5.0, 100) == 5.0


def test_resolve_spec_drops_unknown_axes():
    from repro.core.utils import make_mesh

    mesh = make_mesh((1,), ("data",))
    spec = resolve_spec((("pod", "data"), None, "model"), mesh)
    assert spec == jax.sharding.PartitionSpec("data", None, None)


def test_adapt_for_batch1_decode_config_surgery():
    from repro.launch.dryrun import adapt_for_batch1_decode

    spec = registry.get_spec("gemma2-27b")
    cfg = spec.make_model()
    adapt_for_batch1_decode(cfg)
    attn = cfg.decoder.stack.layer.layers[0].self_attention
    # Batch axes gone from activations; cache seq on "data".
    assert attn.kv_cache_partition[0] is None
    assert attn.kv_cache_partition[1] == "data"
    assert attn.hidden_partition[0] is None
    # Weight partitions untouched.
    assert attn.qkv_weight_partition == ("data", "model")


def test_state_partition_specs_match_state_structure():
    """Every arch's decode-state sharding tree must mirror init_states."""
    from repro.core.module import functional

    for arch in ["qwen2-1.5b", "jamba-1.5-large-398b", "rwkv6-7b",
                 "mixtral-8x7b"]:
        spec = registry.get_spec(arch)
        model = spec.make_smoke().instantiate()
        specs = model.state_partition_specs()
        cache, _ = functional(model, state={}, inputs=(2, 16),
                              method="init_states")

        def paths(tree):
            flat = jax.tree_util.tree_flatten_with_path(
                tree, is_leaf=lambda x: isinstance(x, tuple) or x is None)[0]
            return {jax.tree_util.keystr(p) for p, _ in flat}

        assert paths(specs) == paths(cache), arch


def test_stack_depth_detection():
    from repro.launch.dryrun import stack_depth

    assert stack_depth(registry.get_spec("qwen2-1.5b").make_model()) == 28
    assert stack_depth(registry.get_spec("jamba-1.5-large-398b").make_model()) == 9
    assert stack_depth(registry.get_spec("gemma2-27b").make_model()) == 23
