"""Layer library tests: shapes, correctness, decode equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.module import functional
from repro.kernels import ref as kref
from repro.kernels.registry import KernelConfig
from repro.layers import (
    CausalLM,
    Decoder,
    Embedding,
    FeedForward,
    Linear,
    MultiheadAttention,
    RMSNorm,
    Repeat,
    RotaryEmbedding,
    StackedTransformer,
    TransformerLayer,
    scaled_hidden_dim,
)
from repro.layers.rope import LinearScaledRotaryEmbedding


def run(layer_cfg, inputs, *, state=None, method="forward", training=False, seed=0):
    layer = layer_cfg.instantiate()
    if state is None:
        state = layer.initialize_parameters_recursively(jax.random.PRNGKey(seed))
    out, col = functional(
        layer, state=state, inputs=inputs, is_training=training,
        prng_key=jax.random.PRNGKey(seed + 1), method=method)
    return layer, state, out, col


def test_linear_shapes_and_bias():
    cfg = Linear.default_config().set(name="l", input_dim=8, output_dim=16)
    _, state, out, _ = run(cfg, (jnp.ones((2, 3, 8)),))
    assert out.shape == (2, 3, 16)
    assert state["bias"].shape == (16,)


def test_embedding_attend_tied():
    cfg = Embedding.default_config().set(name="e", num_embeddings=11, dim=6)
    layer = cfg.instantiate()
    state = layer.initialize_parameters_recursively(jax.random.PRNGKey(0))
    ids = jnp.array([[1, 2, 3]])
    emb, _ = functional(layer, state=state, inputs=(ids,))
    logits, _ = functional(layer, state=state, inputs=(emb,), method="attend")
    assert logits.shape == (1, 3, 11)
    assert jnp.argmax(logits[0, 0]) == 1  # embedding should be closest to itself


def test_rmsnorm_matches_ref():
    cfg = RMSNorm.default_config().set(name="n", input_dim=32)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 5, 32))
    _, state, out, _ = run(cfg, (x,))
    np.testing.assert_allclose(
        out, kref.reference_rmsnorm(x, state["scale"]), rtol=1e-6)


def test_rope_rotation_preserves_norm_and_relativity():
    cfg = RotaryEmbedding.default_config().set(name="r", dim=16)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 6, 2, 16))
    _, _, out, _ = run(cfg, (x, jnp.arange(6)), method="apply")
    np.testing.assert_allclose(
        jnp.linalg.norm(out, axis=-1), jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # Relative property: <R(p)q, R(p+k)v> depends only on k.
    q = jax.random.normal(jax.random.PRNGKey(4), (1, 1, 1, 16))
    layer = cfg.instantiate()
    def rot(vec, pos):
        out, _ = functional(layer, state={}, inputs=(vec, jnp.array([pos])), method="apply")
        return out[0, 0, 0]
    d1 = jnp.dot(rot(q, 3), rot(q, 5))
    d2 = jnp.dot(rot(q, 10), rot(q, 12))
    np.testing.assert_allclose(d1, d2, rtol=1e-4)


def test_ffn_swiglu_and_scaled_hidden_dim():
    cfg = FeedForward.default_config().set(
        name="f", input_dim=12, hidden_dim=scaled_hidden_dim(8 / 3, round_to=8),
        activation=("linear", "nn.silu"))
    layer, state, out, _ = run(cfg, (jnp.ones((2, 3, 12)),))
    assert out.shape == (2, 3, 12)
    assert layer.config.hidden_dim == 32  # ceil(32/8)*8
    assert "up_proj0" in state and "up_proj1" in state


ATTN_VARIANTS = [
    dict(num_heads=4, num_kv_heads=4),
    dict(num_heads=4, num_kv_heads=2),  # GQA
    dict(num_heads=4, num_kv_heads=2, sliding_window=8),
    dict(num_heads=4, num_kv_heads=1, logit_softcap=20.0),
]


@pytest.mark.parametrize("variant", ATTN_VARIANTS)
def test_attention_blockwise_equals_ref(variant):
    cfg = MultiheadAttention.default_config().set(
        name="a", input_dim=32, qkv_bias=True,
        kernel=KernelConfig().set(backend="ref"), **variant)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 32))
    layer, state, out_ref, _ = run(cfg, (x,))
    cfg2 = cfg.clone(kernel=KernelConfig().set(
        backend="blockwise", blockwise_chunk_size=4))
    _, _, out_blk, _ = run(cfg2, (x,), state=state)
    np.testing.assert_allclose(np.asarray(out_ref), np.asarray(out_blk), atol=1e-5)


@pytest.mark.parametrize("variant", ATTN_VARIANTS)
def test_attention_decode_matches_forward(variant):
    """prefill + extend_step token-by-token == full forward (unified
    train/inference, paper §6)."""
    S, D = 12, 32
    cfg = MultiheadAttention.default_config().set(
        name="a", input_dim=D, kv_cache_dtype=jnp.float32, **variant)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, S, D))
    layer, state, full, _ = run(cfg, (x,))

    cache, _ = functional(layer, state=state, inputs=(2, S), method="init_states")
    prefix = 5
    cache, y_pre, = None, None
    cache0, _ = functional(layer, state=state, inputs=(2, S), method="init_states")
    (cache, y_pre), _ = functional(
        layer, state=state, inputs={"state": cache0, "x": x[:, :prefix]}, method="prefill")
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(full[:, :prefix]), atol=2e-3)
    ys = [y_pre]
    for t in range(prefix, S):
        (cache, y), _ = functional(
            layer, state=state,
            inputs={"state": cache, "x_step": x[:, t:t + 1]}, method="extend_step")
        ys.append(y)
    decoded = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(decoded), np.asarray(full), atol=2e-3)


def test_sliding_window_cache_is_bounded():
    cfg = MultiheadAttention.default_config().set(
        name="a", input_dim=16, num_heads=2, sliding_window=4)
    layer = cfg.instantiate()
    state = layer.initialize_parameters_recursively(jax.random.PRNGKey(0))
    cache, _ = functional(layer, state=state, inputs=(1, 64), method="init_states")
    assert cache["k"].shape[1] == 4, "SWA cache must be window-sized (long_500k enabler)"


def _tiny_layer_cfg(dim=32, moe=False):
    cfg = TransformerLayer.default_config().set(name="t", input_dim=dim)
    cfg.self_attention.set(num_heads=4, num_kv_heads=2)
    cfg.feed_forward.set(hidden_dim=dim * 2, activation=("linear", "nn.silu"))
    return cfg


def test_transformer_layer_forward_and_decode():
    cfg = _tiny_layer_cfg()
    cfg.self_attention.kv_cache_dtype = jnp.float32
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 8, 32))
    layer, state, full, _ = run(cfg, (x,))
    assert full.shape == x.shape
    cache, _ = functional(layer, state=state, inputs=(2, 8), method="init_states")
    (cache, y0), _ = functional(layer, state=state,
                                inputs={"state": cache, "x": x[:, :4]}, method="prefill")
    ys = [y0]
    for t in range(4, 8):
        (cache, y), _ = functional(layer, state=state,
                                   inputs={"state": cache, "x_step": x[:, t:t + 1]},
                                   method="extend_step")
        ys.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, 1)), np.asarray(full), atol=2e-3)


def test_repeat_matches_stacked_loop():
    """scan-over-layers == python loop with identical per-layer params."""
    layer_cfg = _tiny_layer_cfg()
    L = 3
    rep_cfg = Repeat.default_config().set(
        name="rep", layer=layer_cfg, num_layers=L, remat_policy=None)
    rep = rep_cfg.instantiate()
    rep_state = rep.initialize_parameters_recursively(jax.random.PRNGKey(1))

    stk_cfg = StackedTransformer.default_config().set(
        name="stk", layers=[layer_cfg.clone() for _ in range(L)])
    stk = stk_cfg.instantiate()
    stk_state = {
        f"layer{i}": jax.tree.map(lambda a: a[i], rep_state["layer"]) for i in range(L)
    }
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 8, 32))
    out_rep, _ = functional(rep, state=rep_state, inputs=(x,))
    out_stk, _ = functional(stk, state=stk_state, inputs=(x,))
    np.testing.assert_allclose(np.asarray(out_rep), np.asarray(out_stk), atol=1e-5)


def test_repeat_remat_same_loss_and_grads():
    layer_cfg = _tiny_layer_cfg()
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 8, 32))

    def loss_fn(state, cfg):
        rep = cfg.instantiate()
        out, _ = functional(rep, state=state, inputs=(x,), is_training=True,
                            prng_key=jax.random.PRNGKey(0))
        return jnp.sum(out ** 2)

    cfg_a = Repeat.default_config().set(name="r", layer=layer_cfg, num_layers=2,
                                        remat_policy=None)
    cfg_b = cfg_a.clone(remat_policy="full")
    state = cfg_a.instantiate().initialize_parameters_recursively(jax.random.PRNGKey(1))
    la, ga = jax.value_and_grad(loss_fn)(state, cfg_a)
    lb, gb = jax.value_and_grad(loss_fn)(state, cfg_b)
    np.testing.assert_allclose(la, lb, rtol=1e-6)
    for (pa, pb) in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
        # Remat recomputes the forward, which XLA may fuse/reassociate
        # differently; grads O(10-100) match to ~1e-4 absolute.
        np.testing.assert_allclose(np.asarray(pa), np.asarray(pb), atol=1e-4,
                                   rtol=1e-4)


def _tiny_lm_cfg(vocab=64, dim=32, L=2):
    layer_cfg = _tiny_layer_cfg(dim)
    layer_cfg.self_attention.kv_cache_dtype = jnp.float32
    dec = Decoder.default_config().set(
        name="d", vocab_size=vocab, dim=dim,
        stack=Repeat.default_config().set(layer=layer_cfg, num_layers=L,
                                          remat_policy=None))
    return CausalLM.default_config().set(name="lm", decoder=dec)


def test_causal_lm_loss_and_decode_equivalence():
    cfg = _tiny_lm_cfg()
    model = cfg.instantiate()
    state = model.initialize_parameters_recursively(jax.random.PRNGKey(0))
    ids = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, 64)
    batch = {"input_ids": ids, "labels": jnp.roll(ids, -1, axis=1)}
    (loss, aux), col = functional(model, state=state, inputs=(batch,), is_training=True,
                                  prng_key=jax.random.PRNGKey(2))
    assert jnp.isfinite(loss)
    assert aux["logits"].shape == (2, 10, 64)
    # decode path == forward path logits
    logits_fwd = aux["logits"]
    cache, _ = functional(model, state=state, inputs=(2, 10), method="init_states")
    (cache, lg), _ = functional(model, state=state,
                                inputs={"state": cache, "input_ids": ids[:, :6]},
                                method="prefill")
    outs = [lg]
    for t in range(6, 10):
        (cache, lg), _ = functional(model, state=state,
                                    inputs={"state": cache, "ids_step": ids[:, t:t + 1]},
                                    method="extend_step")
        outs.append(lg)
    decoded = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(decoded), np.asarray(logits_fwd), atol=3e-3)


def test_rope_variant_swap_is_pure_config():
    """The paper's O(1) claim at layer level: swapping the RoPE child changes
    behaviour without touching attention code."""
    from repro.core.config import replace_config

    cfg = _tiny_lm_cfg()
    n = replace_config(
        cfg, target=RotaryEmbedding,
        new_cfg=LinearScaledRotaryEmbedding.default_config().set(scaling_factor=4.0),
        propagate=("dim", "theta"))
    assert n == 1  # one template inside the repeated layer
    model = cfg.instantiate()
    assert type(model.decoder.stack.layer.self_attention.rope).__name__ == \
        "LinearScaledRotaryEmbedding"


def test_chunked_loss_matches_full():
    """Token-chunked CE (memory lever for 256k vocab) == single-shot CE."""
    cfg = _tiny_lm_cfg()
    model = cfg.instantiate()
    state = model.initialize_parameters_recursively(jax.random.PRNGKey(0))
    ids = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 64)
    batch = {"input_ids": ids, "labels": jnp.roll(ids, -1, 1)}
    (loss_full, _), _ = functional(model, state=state, inputs=(batch,))
    cfg2 = cfg.clone(loss_chunk_size=4)
    model2 = cfg2.instantiate()
    (loss_chunk, aux), _ = functional(model2, state=state, inputs=(batch,))
    np.testing.assert_allclose(np.asarray(loss_chunk), np.asarray(loss_full),
                               rtol=1e-6)
    assert aux["logits"] is None

    # Gradients agree too (remat inside the chunk scan).
    def lf(s, c):
        m = c.instantiate()
        (l, _), _ = functional(m, state=s, inputs=(batch,))
        return l

    g1 = jax.grad(lf)(state, cfg)
    g2 = jax.grad(lf)(state, cfg2)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
