"""Decode fast path: flash-decode kernel parity, scan-generate equivalence,
bucketed admission, and the no-logits-materialization guarantee.

Kernel tests run the Pallas body under interpret=True (CPU), which executes
the exact block decomposition and online-softmax updates Mosaic would run on
TPU.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.module import functional
from repro.inference.engine import InferenceEngine, Request
from repro.kernels import ops, ref
from repro.kernels.registry import KernelConfig

# interpret=True -> the registry auto-selects pallas:interpret (the exact
# Mosaic block decomposition, executed on CPU).
INTERP = KernelConfig().set(interpret=True)
from repro.layers import CausalLM, Decoder, Repeat, TransformerLayer


def _mk_qkv(key, B, Sq, T, Hq, Hkv, D, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), dtype)
    return q, k, v


def _check_parity(q, k, v, q_pos, k_pos, **kw):
    out = ops.decode_attention(
        q, k, v, q_positions=q_pos, k_positions=k_pos, kernel=INTERP, **kw)
    expect = ref.reference_attention(
        q, k, v, q_positions=q_pos, k_positions=k_pos, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


# ------------------------------- kernel parity -------------------------------


@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (4, 2), (4, 1)])
def test_flash_decode_gqa_parity(Hq, Hkv):
    """GQA ratios 1/2/4: rows of one q block cover the whole KV group."""
    B, T, D = 2, 33, 16
    q, k, v = _mk_qkv(jax.random.PRNGKey(0), B, 1, T, Hq, Hkv, D)
    k_pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    q_pos = jnp.full((B, 1), T)
    _check_parity(q, k, v, q_pos, k_pos)


def test_flash_decode_multi_step_causal():
    """S' > 1 decode steps mask causally among themselves."""
    B, Sq, T, D = 1, 3, 16, 8
    q, k, v = _mk_qkv(jax.random.PRNGKey(1), B, Sq, T, 4, 2, D)
    # Cache holds positions 0..12 plus the 3 new tokens at 13,14,15.
    k_pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    q_pos = jnp.asarray([[13, 14, 15]])
    _check_parity(q, k, v, q_pos, k_pos)


def test_flash_decode_ring_wraparound():
    """Ring layout: slot s holds position p with p % T == s — masking reads
    the pos tensor, so physical order is irrelevant."""
    B, T, D = 2, 8, 16
    q, k, v = _mk_qkv(jax.random.PRNGKey(2), B, 1, T, 4, 2, D)
    # 11 tokens written into an 8-slot ring: slots hold [8,9,10,3,4,5,6,7].
    ring = jnp.asarray([8, 9, 10, 3, 4, 5, 6, 7])
    k_pos = jnp.broadcast_to(ring, (B, T))
    q_pos = jnp.full((B, 1), 11)
    _check_parity(q, k, v, q_pos, k_pos, sliding_window=8)


def test_flash_decode_sliding_window():
    B, T, D = 1, 40, 16
    q, k, v = _mk_qkv(jax.random.PRNGKey(3), B, 1, T, 2, 2, D)
    k_pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    q_pos = jnp.full((B, 1), T)
    _check_parity(q, k, v, q_pos, k_pos, sliding_window=7)


def test_flash_decode_softcap_and_scale():
    B, T, D = 1, 24, 16
    q, k, v = _mk_qkv(jax.random.PRNGKey(4), B, 1, T, 4, 2, D)
    k_pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    q_pos = jnp.full((B, 1), T)
    _check_parity(q, k, v, q_pos, k_pos, logit_softcap=30.0, scale=0.2)


def test_flash_decode_partial_and_empty_slots():
    """Slots with pos = -1 (not yet written) are masked; a fully-masked row
    (empty continuous-batching slot) returns zeros — finite, never NaN."""
    B, T, D = 2, 12, 8
    q, k, v = _mk_qkv(jax.random.PRNGKey(5), B, 1, T, 4, 2, D)
    valid = jnp.asarray([0, 1, 2, 3] + [-1] * (T - 4))
    k_pos = jnp.stack([valid, jnp.full((T,), -1)])  # row 1: empty slot
    q_pos = jnp.asarray([[4], [0]])
    out = ops.decode_attention(q, k, v, q_positions=q_pos, k_positions=k_pos,
                               kernel=INTERP)
    expect = ref.reference_attention(q, k, v, q_positions=q_pos,
                                     k_positions=k_pos)
    # Row 0 has valid keys: exact parity with the reference oracle.
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(expect[0]),
                               atol=2e-5, rtol=2e-5)
    # Row 1 is fully masked: the kernel defines the output as zeros (the
    # reference degenerates to a uniform average; both are unused downstream).
    assert np.isfinite(np.asarray(out[1])).all()
    np.testing.assert_allclose(np.asarray(out[1]), 0.0, atol=1e-6)


def test_flash_decode_bf16_inputs():
    B, T, D = 1, 32, 16
    q, k, v = _mk_qkv(jax.random.PRNGKey(6), B, 1, T, 4, 2, D, jnp.bfloat16)
    k_pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    q_pos = jnp.full((B, 1), T)
    out = ops.decode_attention(q, k, v, q_positions=q_pos, k_positions=k_pos,
                               kernel=INTERP)
    expect = ref.reference_attention(q, k, v, q_positions=q_pos,
                                     k_positions=k_pos)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=2e-2)


# ------------------------- flash_attention dispatch --------------------------


def test_flash_attention_equal_positions_uses_kernel():
    """Equal-by-value (but distinct) position arrays must NOT fall back to
    the O(S*T)-materializing reference path."""
    B, S, H, D = 1, 128, 2, 32
    q, k, v = _mk_qkv(jax.random.PRNGKey(7), B, S, S, H, H, D)
    # Two equal-valued but DISTINCT concrete arrays (the caller pattern the
    # old identity check broke on). Closed over — i.e. concrete — inside the
    # traced function; traced positions still fall back conservatively.
    qp, kp = jnp.arange(S), jnp.arange(S)
    assert qp is not kp
    jaxpr = jax.make_jaxpr(
        lambda q, k, v: ops.flash_attention(
            q, k, v, q_positions=qp, k_positions=kp, kernel=INTERP))(q, k, v)
    assert "pallas_call" in str(jaxpr), \
        "equal-but-distinct positions fell back to the reference path"
    out = ops.flash_attention(q, k, v, q_positions=qp, k_positions=kp,
                              kernel=INTERP)
    expect = ref.reference_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


# --------------------------- engine: scan generate ---------------------------


def _tiny_lm(vocab=48, dim=32, L=2, window=None, decode_backend="ref"):
    layer = TransformerLayer.default_config().set(input_dim=dim)
    kernel = KernelConfig().set(
        op_overrides={"attention.decode": decode_backend},
        interpret=(decode_backend == "pallas"))
    layer.self_attention.set(num_heads=4, num_kv_heads=2, kernel=kernel,
                             kv_cache_dtype=jnp.float32, sliding_window=window)
    layer.feed_forward.set(hidden_dim=dim * 2)
    return CausalLM.default_config().set(
        name="lm",
        decoder=Decoder.default_config().set(
            vocab_size=vocab, dim=dim,
            stack=Repeat.default_config().set(layer=layer, num_layers=L,
                                              remat_policy=None)))


def _engine(model_cfg, max_len=32, slots=4):
    cfg = InferenceEngine.default_config().set(
        name="engine", model=model_cfg, max_len=max_len, slots=slots)
    engine = cfg.instantiate()
    params = engine.model.initialize_parameters_recursively(jax.random.PRNGKey(0))
    engine.load(params)
    return engine, params


def _stepwise_generate(engine, prompts, max_new_tokens, temperature, seed):
    """The pre-scan per-token host loop (one dispatch + sync per token) —
    the semantics oracle for the fused scan decode loop."""
    params = engine._params
    cache = engine.init_cache(prompts.shape[0])
    prefill = jax.jit(engine.prefill_fn())
    decode = jax.jit(engine.serve_step_fn())
    cache, logits = prefill(params, cache, jnp.asarray(prompts))
    key = jax.random.PRNGKey(seed)
    outs = []
    for _ in range(max_new_tokens):
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        outs.append(nxt)
        cache, logits = decode(params, cache, nxt[:, None])
    return np.asarray(jnp.stack(outs, axis=1))


def test_scan_generate_matches_stepwise_greedy():
    engine, _ = _engine(_tiny_lm())
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 48))
    tokens, _ = engine.generate(prompts, max_new_tokens=7)
    expect = _stepwise_generate(engine, prompts, 7, 0.0, 0)
    np.testing.assert_array_equal(tokens, expect)


def test_scan_generate_matches_stepwise_temperature():
    """Fixed-seed temperature sampling: the scan loop threads the PRNG key
    through its carry with the same split order as the host loop."""
    engine, _ = _engine(_tiny_lm())
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, 48))
    tokens, _ = engine.generate(prompts, max_new_tokens=6, temperature=0.7,
                                seed=5)
    expect = _stepwise_generate(engine, prompts, 6, 0.7, 5)
    np.testing.assert_array_equal(tokens, expect)


def test_generate_flash_decode_matches_ref_impl():
    """The decode backend is semantics-free: pallas (interpret) == ref."""
    engine_ref, _ = _engine(_tiny_lm(decode_backend="ref"))
    engine_fd, _ = _engine(_tiny_lm(decode_backend="pallas"))
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, 48))
    t_ref, _ = engine_ref.generate(prompts, max_new_tokens=6)
    t_fd, _ = engine_fd.generate(prompts, max_new_tokens=6)
    np.testing.assert_array_equal(t_ref, t_fd)


def test_generate_flash_decode_sliding_window_matches_ref():
    engine_ref, _ = _engine(_tiny_lm(window=8, decode_backend="ref"),
                            max_len=64)
    engine_fd, _ = _engine(_tiny_lm(window=8, decode_backend="pallas"),
                           max_len=64)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, 48))
    t_ref, _ = engine_ref.generate(prompts, max_new_tokens=6)
    t_fd, _ = engine_fd.generate(prompts, max_new_tokens=6)
    np.testing.assert_array_equal(t_ref, t_fd)


def _jaxpr_shapes(jaxpr, acc):
    for eqn in jaxpr.eqns:
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                acc.add(tuple(aval.shape))
        for param in eqn.params.values():
            inner = getattr(param, "jaxpr", None)
            if inner is not None:
                _jaxpr_shapes(inner, acc)
            if isinstance(param, (list, tuple)):
                for p in param:
                    inner = getattr(p, "jaxpr", None)
                    if inner is not None:
                        _jaxpr_shapes(inner, acc)
    return acc


def test_flash_decode_never_materializes_decode_logits():
    """The acceptance guarantee: with the pallas decode backend no
    intermediate of shape (B, Hkv, G, S', T) exists anywhere in the decode
    step program; with 'ref' it does."""
    B, T = 2, 32
    shapes = {}
    for impl in ("ref", "pallas"):
        engine, params = _engine(_tiny_lm(decode_backend=impl), max_len=T)
        cache = engine.init_cache(B)
        step = engine.serve_step_fn()
        ids = jnp.zeros((B, 1), jnp.int32)
        jaxpr = jax.make_jaxpr(step)(params, cache, ids)
        shapes[impl] = _jaxpr_shapes(jaxpr.jaxpr, set())
    logits_shape = (B, 2, 2, 1, T)  # (B, Hkv, G, S'=1, T)
    assert logits_shape in shapes["ref"], \
        "expected the ref decode path to materialize attention logits"
    assert logits_shape not in shapes["pallas"], \
        "flash_decode materialized the (B,Hkv,G,S',T) logits tensor"


# ------------------------- engine: bucketed admission ------------------------


def test_bucket_len_policy():
    engine, _ = _engine(_tiny_lm(), max_len=48)
    assert engine._bucket_len(1) == 8
    assert engine._bucket_len(8) == 8
    assert engine._bucket_len(9) == 16
    assert engine._bucket_len(17) == 32
    # Prompts longer than max_len still bucket (ring cache keeps the last
    # T valid tokens, recurrent mixers consume the whole prompt).
    assert engine._bucket_len(49) == 64


def test_serve_prompt_longer_than_max_len_matches_generate():
    """Over-long prompts are served through the ring cache, exactly like
    batched generation (a per-request error must not abort the batch)."""
    engine, _ = _engine(_tiny_lm(), max_len=16, slots=2)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, 48, size=(n,)) for n in (24, 6)]
    reqs = [Request(request_id=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    results = engine.serve(reqs)
    for i, res in enumerate(results):
        expect, _ = engine.generate(prompts[i][None, :], max_new_tokens=4)
        np.testing.assert_array_equal(np.asarray(res.tokens), expect[0])


def test_serve_mixed_prompt_lengths_matches_generate():
    """Bucketed admission is exact: prompts of different lengths (padded to
    different buckets) produce the same greedy tokens as unpadded
    single-request generation."""
    engine, _ = _engine(_tiny_lm(), max_len=32, slots=2)
    rng = np.random.default_rng(0)
    lens = [5, 9, 16, 3, 12]
    prompts = [rng.integers(0, 48, size=(n,)) for n in lens]
    reqs = [Request(request_id=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    results = engine.serve(reqs)
    for i, res in enumerate(results):
        expect, _ = engine.generate(prompts[i][None, :], max_new_tokens=4)
        np.testing.assert_array_equal(np.asarray(res.tokens), expect[0])


def test_serve_mixed_lengths_rwkv():
    """Recurrent mixers: bucket padding must not pollute the wkv/shift state
    (identity transitions on padded steps)."""
    from repro.layers.rwkv import RWKV6Block

    block = RWKV6Block.default_config().set(input_dim=32)
    block.time_mix.set(head_dim=16, decay_lora_dim=8)
    block.time_mix.kernel.set(wkv_chunk_size=4)
    block.channel_mix.set(hidden_dim=64)
    model = CausalLM.default_config().set(
        name="lm",
        decoder=Decoder.default_config().set(
            vocab_size=48, dim=32,
            stack=Repeat.default_config().set(layer=block, num_layers=2,
                                              remat_policy=None)))
    engine, _ = _engine(model, max_len=32, slots=2)
    rng = np.random.default_rng(1)
    lens = [6, 11, 3]
    prompts = [rng.integers(0, 48, size=(n,)) for n in lens]
    reqs = [Request(request_id=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    results = engine.serve(reqs)
    for i, res in enumerate(results):
        expect, _ = engine.generate(prompts[i][None, :], max_new_tokens=4)
        np.testing.assert_array_equal(np.asarray(res.tokens), expect[0])


def test_serve_mixed_lengths_mamba():
    from repro.layers.ssm import MambaMixer

    layer = TransformerLayer.default_config().set(input_dim=32)
    # scan_chunk_size=8: the 16-bucket admissions exercise the CHUNKED
    # masked scan (long buckets must not materialize (B,S,di,N) states).
    layer.self_attention = MambaMixer.default_config().set(
        state_dim=8, conv_width=3, scan_chunk_size=8)
    layer.feed_forward.set(hidden_dim=64)
    model = CausalLM.default_config().set(
        name="lm",
        decoder=Decoder.default_config().set(
            vocab_size=48, dim=32,
            stack=Repeat.default_config().set(layer=layer, num_layers=2,
                                              remat_policy=None)))
    engine, _ = _engine(model, max_len=32, slots=2)
    rng = np.random.default_rng(2)
    lens = [7, 12, 4]
    prompts = [rng.integers(0, 48, size=(n,)) for n in lens]
    reqs = [Request(request_id=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    results = engine.serve(reqs)
    for i, res in enumerate(results):
        expect, _ = engine.generate(prompts[i][None, :], max_new_tokens=4)
        np.testing.assert_array_equal(np.asarray(res.tokens), expect[0])


def test_decode_attention_requires_positions():
    q, k, v = _mk_qkv(jax.random.PRNGKey(8), 1, 1, 8, 2, 2, 8)
    with pytest.raises(ValueError, match="explicit q_positions"):
        ops.decode_attention(q, k, v, q_positions=None,
                             k_positions=jnp.arange(8), kernel=INTERP)


def test_flash_decode_allows_single_device_mesh():
    """The sharded-cache guard only trips on real >1-way sharding: a
    1-device mesh (names resolve but sizes are 1) must pass."""
    from repro.core.utils import make_mesh, set_mesh

    engine, _ = _engine(_tiny_lm(decode_backend="pallas"))
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(9), (2, 8), 0, 48))
    with set_mesh(make_mesh((1,), ("data",))):
        tokens, _ = engine.generate(prompts, max_new_tokens=3)
    assert tokens.shape == (2, 3)


def test_admission_is_compile_bounded():
    """Admissions at different slots / true lengths within one bucket reuse
    one compiled program (traced scalars, not shape specializations)."""
    engine, _ = _engine(_tiny_lm(), max_len=32, slots=2)
    rng = np.random.default_rng(3)
    # Lengths 5..8 share the 8-bucket: first admit compiles, rest must not.
    reqs = [Request(request_id=i, prompt=rng.integers(0, 48, size=(5 + i,)),
                    max_new_tokens=2) for i in range(4)]
    engine.serve([reqs[0]])
    admit = engine._jit_fns["admit"]
    compiles_after_first = admit._cache_size()
    engine.serve(reqs[1:])
    assert admit._cache_size() == compiles_after_first, \
        "same-bucket admissions recompiled"
