"""Test-suite conftest: ``multiprocess`` marker + hypothesis fallback shim.

``@pytest.mark.multiprocess`` marks tests that spawn real worker
subprocesses coordinating through the filesystem (the elastic-training
drills). A hung collective there would otherwise block the whole suite, so
each such test runs under a SIGALRM watchdog (default 300 s, override with
``@pytest.mark.multiprocess(timeout=N)``) that fails the test instead of
hanging it. Deselect them with ``-m "not multiprocess"`` for a fast pass.

``@pytest.mark.heavy`` is the tier-1 runtime guard for expensive in-suite
tests (forced multi-device subprocess shardings, long compiles): the same
SIGALRM watchdog with a 240 s default, plus an opt-out — set
``REPRO_SKIP_HEAVY=1`` (or deselect with ``-m "not heavy"``) to skip them
when iterating locally.

Hypothesis: some environments (including the CI container) don't ship
``hypothesis``; the property tests then degraded to hard collection errors
for whole test modules. When the real library is importable we use it
untouched; otherwise we install a tiny deterministic stand-in into
``sys.modules`` *before* test modules import it. The shim runs each
``@given`` test over ``max_examples`` pseudo-random draws from a fixed seed
— weaker than real shrinking/coverage, but it keeps the properties
exercised everywhere.
"""

import os
import random
import signal
import sys
import types

import pytest

_MULTIPROCESS_DEFAULT_TIMEOUT_S = 300


_HEAVY_DEFAULT_TIMEOUT_S = 240


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multiprocess(timeout=300): test spawns worker subprocesses; runs "
        "under a SIGALRM watchdog so a dead collective fails instead of "
        "hanging the suite")
    config.addinivalue_line(
        "markers",
        "heavy(timeout=240): tier-1 runtime guard for expensive in-suite "
        "tests (forced multi-device subprocesses, long compiles); runs "
        "under a SIGALRM watchdog and is skipped when REPRO_SKIP_HEAVY "
        "is set")


@pytest.fixture(autouse=True)
def _multiprocess_watchdog(request):
    marker = request.node.get_closest_marker("multiprocess")
    if marker is None:
        marker = request.node.get_closest_marker("heavy")
        if marker is not None and os.environ.get("REPRO_SKIP_HEAVY"):
            pytest.skip("REPRO_SKIP_HEAVY set: skipping heavy tier-1 test")
        default_timeout = _HEAVY_DEFAULT_TIMEOUT_S
    else:
        default_timeout = _MULTIPROCESS_DEFAULT_TIMEOUT_S
    if marker is None or not hasattr(signal, "SIGALRM"):
        yield
        return
    timeout = int(marker.kwargs.get("timeout", default_timeout))

    def on_alarm(signum, frame):
        pytest.fail(f"multiprocess test exceeded {timeout}s watchdog "
                    f"(dead worker / hung collective?)", pytrace=False)

    prev = signal.signal(signal.SIGALRM, on_alarm)
    signal.alarm(timeout)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, prev)


def _install_hypothesis_shim():
    class _Strategy:
        """Minimal SearchStrategy: a callable drawing one example."""

        def __init__(self, draw_fn):
            self._draw = draw_fn

        def example_with(self, rng):
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

        def filter(self, pred):
            def draw(rng):
                for _ in range(1000):
                    x = self._draw(rng)
                    if pred(x):
                        return x
                raise ValueError("filter predicate never satisfied")

            return _Strategy(draw)

    def integers(min_value=-(2 ** 31), max_value=2 ** 31 - 1):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def floats(min_value=None, max_value=None, *, allow_nan=True,
               allow_infinity=True, width=64):
        lo = -1e6 if min_value is None else min_value
        hi = 1e6 if max_value is None else max_value
        return _Strategy(lambda rng: rng.uniform(lo, hi))

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

    def text(alphabet="abcdefghijklmnopqrstuvwxyz", min_size=0, max_size=10):
        chars = list(alphabet)

        def draw(rng):
            n = rng.randint(min_size, max_size)
            return "".join(chars[rng.randrange(len(chars))] for _ in range(n))

        return _Strategy(draw)

    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example_with(rng) for _ in range(n)]

        return _Strategy(draw)

    def just(value):
        return _Strategy(lambda rng: value)

    def one_of(*strategies):
        flat = []
        for s in strategies:
            flat.extend(s if isinstance(s, (list, tuple)) else [s])
        return _Strategy(
            lambda rng: flat[rng.randrange(len(flat))].example_with(rng))

    def tuples(*strategies):
        return _Strategy(
            lambda rng: tuple(s.example_with(rng) for s in strategies))

    def composite(fn):
        def builder(*args, **kwargs):
            def draw_one(rng):
                draw = lambda strat: strat.example_with(rng)  # noqa: E731
                return fn(draw, *args, **kwargs)

            return _Strategy(draw_one)

        return builder

    _DEFAULT_MAX_EXAMPLES = 10

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    def given(*strategies, **kw_strategies):
        def deco(fn):
            def wrapper(*outer_args, **outer_kwargs):
                # outer_* come from pytest (fixtures / parametrize) and are
                # forwarded ahead of the shim-drawn values, matching real
                # hypothesis' argument ordering. @settings may sit above OR
                # below @given, so check the wrapper's attribute too.
                n = getattr(wrapper, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples",
                                    _DEFAULT_MAX_EXAMPLES))
                for i in range(n):
                    rng = random.Random(0xA11CE + 7919 * i)
                    args = [s.example_with(rng) for s in strategies]
                    kwargs = {k: s.example_with(rng)
                              for k, s in kw_strategies.items()}
                    fn(*outer_args, *args, **outer_kwargs, **kwargs)

            # NOTE: no functools.wraps — pytest must see a zero-arg signature
            # (the original's params would otherwise look like fixtures).
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._shim_wrapped = fn
            return wrapper

        return deco

    def assume(condition):
        if not condition:
            raise AssertionError("hypothesis-shim: assume() failed "
                                 "(shim cannot discard examples)")

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.HealthCheck = types.SimpleNamespace(all=lambda: [])
    mod.__is_shim__ = True

    st_mod = types.ModuleType("hypothesis.strategies")
    for name, obj in [("integers", integers), ("floats", floats),
                      ("booleans", booleans), ("sampled_from", sampled_from),
                      ("text", text), ("lists", lists), ("just", just),
                      ("one_of", one_of), ("tuples", tuples),
                      ("composite", composite)]:
        setattr(st_mod, name, obj)
    mod.strategies = st_mod

    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


try:  # pragma: no cover - depends on environment
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_shim()
