"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

All kernels run in interpret=True mode on CPU (the kernel body executes with
real Python/jnp semantics), which validates the block decomposition, masking,
and online-softmax logic exactly as Mosaic would execute it on TPU.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref
from repro.kernels.registry import KernelConfig
from repro.kernels.flash_attention import flash_attention, flash_attention_forward
from repro.kernels.rmsnorm import rmsnorm_forward


def _mk_qkv(key, B, S, T, Hq, Hkv, D, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), dtype)
    return q, k, v


SHAPE_SWEEP = [
    # B, S, Hq, Hkv, D, block_q, block_k
    (1, 128, 1, 1, 64, 64, 64),
    (2, 256, 4, 2, 32, 128, 128),
    (1, 384, 4, 1, 64, 128, 128),   # ragged: S not multiple of block
    (2, 100, 2, 2, 32, 64, 64),     # pad both dims
    (1, 256, 8, 2, 128, 128, 64),   # GQA 4:1, MXU-aligned D
]


@pytest.mark.parametrize("B,S,Hq,Hkv,D,bq,bk", SHAPE_SWEEP)
def test_flash_matches_ref_causal(B, S, Hq, Hkv, D, bq, bk):
    q, k, v = _mk_qkv(jax.random.PRNGKey(0), B, S, S, Hq, Hkv, D)
    out = flash_attention_forward(q, k, v, causal=True, block_q=bq, block_k=bk,
                                  interpret=True)
    expect = ref.reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [16, 64, 100])
def test_flash_sliding_window(window):
    q, k, v = _mk_qkv(jax.random.PRNGKey(1), 1, 256, 256, 2, 2, 32)
    out = flash_attention_forward(q, k, v, causal=True, sliding_window=window,
                                  block_q=64, block_k=64, interpret=True)
    expect = ref.reference_attention(q, k, v, causal=True, sliding_window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


def test_flash_softcap_and_noncausal():
    q, k, v = _mk_qkv(jax.random.PRNGKey(2), 2, 128, 128, 2, 1, 32)
    out = flash_attention_forward(q, k, v, causal=False, logit_softcap=30.0,
                                  block_q=64, block_k=64, interpret=True)
    expect = ref.reference_attention(q, k, v, causal=False, logit_softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


def test_flash_bf16_io():
    q, k, v = _mk_qkv(jax.random.PRNGKey(3), 1, 128, 128, 2, 2, 64, dtype=jnp.bfloat16)
    out = flash_attention_forward(q, k, v, causal=True, interpret=True)
    assert out.dtype == jnp.bfloat16
    expect = ref.reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=2e-2)


@given(
    st.integers(1, 2),                     # B
    st.sampled_from([64, 96, 128, 200]),   # S
    st.sampled_from([(2, 1), (2, 2), (4, 2)]),  # heads
    st.sampled_from([32, 64]),             # D
    st.booleans(),                         # causal
)
@settings(max_examples=12, deadline=None)
def test_flash_property_sweep(B, S, heads, D, causal):
    Hq, Hkv = heads
    q, k, v = _mk_qkv(jax.random.PRNGKey(S * 7 + D), B, S, S, Hq, Hkv, D)
    out = flash_attention_forward(q, k, v, causal=causal, block_q=64, block_k=64,
                                  interpret=True)
    expect = ref.reference_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=3e-5, rtol=3e-5)


# ------------------------ flash attention backward ---------------------------
#
# The recompute-based custom_vjp (dKV + dQ Pallas passes) must match
# jax.grad of the reference oracle — this is what makes the pallas backend
# legal as the *training* kernel, not just the serving path.


def _grad_parity(B, S, Hq, Hkv, D, *, causal=True, window=None, cap=None,
                 bq=64, bk=64, tol=3e-4, seed=0):
    q, k, v = _mk_qkv(jax.random.PRNGKey(seed), B, S, S, Hq, Hkv, D)
    do = jax.random.normal(jax.random.PRNGKey(seed + 1), q.shape)

    def f_flash(q, k, v):
        return jnp.sum(do * flash_attention(
            q, k, v, causal=causal, sliding_window=window, logit_softcap=cap,
            block_q=bq, block_k=bk, interpret=True))

    def f_ref(q, k, v):
        return jnp.sum(do * ref.reference_attention(
            q, k, v, causal=causal, sliding_window=window, logit_softcap=cap))

    gf = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gf, gr, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=tol, rtol=tol, err_msg=name)


@pytest.mark.parametrize("B,S,Hq,Hkv,D,bq,bk", SHAPE_SWEEP)
def test_flash_backward_matches_ref_grads(B, S, Hq, Hkv, D, bq, bk):
    _grad_parity(B, S, Hq, Hkv, D, bq=bq, bk=bk)


def test_flash_backward_gqa_sliding_window():
    _grad_parity(1, 256, 4, 1, 64, window=64)


def test_flash_backward_softcap_noncausal():
    _grad_parity(2, 128, 2, 1, 32, causal=False, cap=30.0)


def test_flash_backward_ragged_padding():
    # S not a block multiple: padded q rows/k cols must contribute nothing.
    _grad_parity(1, 100, 2, 2, 32, tol=5e-4)


def test_flash_value_and_grad_under_jit():
    """The flash kernel composes with jit + value_and_grad (the train step)."""
    q, k, v = _mk_qkv(jax.random.PRNGKey(5), 1, 128, 128, 2, 2, 32)

    @jax.jit
    def loss(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal=True, interpret=True) ** 2)

    val, grads = jax.value_and_grad(loss, argnums=(0, 1, 2))(q, k, v)
    ref_val = jnp.sum(ref.reference_attention(q, k, v, causal=True) ** 2)
    np.testing.assert_allclose(float(val), float(ref_val), rtol=1e-5)
    assert all(np.isfinite(np.asarray(g)).all() for g in grads)


def test_attention_layer_flash_grads_match_ref_impl():
    """End-to-end layer gradients: pallas backend == ref backend under grad."""
    from repro.core.module import functional
    from repro.layers import MultiheadAttention

    cfg = MultiheadAttention.default_config().set(
        name="a", input_dim=64, num_heads=4, num_kv_heads=2,
        kernel=KernelConfig().set(backend="pallas", interpret=True))
    layer = cfg.instantiate()
    state = layer.initialize_parameters_recursively(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 128, 64))

    def loss(state, layer):
        out, _ = functional(layer, state=state, inputs=(x,))
        return jnp.sum(out ** 2)

    g_flash = jax.grad(loss)(state, layer)
    g_ref = jax.grad(loss)(
        state, cfg.clone(kernel=KernelConfig().set(backend="ref")).instantiate())
    for a, b in zip(jax.tree.leaves(g_flash), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


# ------------------------------ RMSNorm --------------------------------------


@pytest.mark.parametrize("shape,block_rows", [
    ((4, 7, 64), 8),
    ((2, 256, 128), 256),
    ((1, 100, 32), 64),   # row padding
])
def test_rmsnorm_kernel_matches_ref(shape, block_rows):
    x = jax.random.normal(jax.random.PRNGKey(4), shape)
    scale = jax.random.normal(jax.random.PRNGKey(5), (shape[-1],))
    out = rmsnorm_forward(x, scale, block_rows=block_rows, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.reference_rmsnorm(x, scale)),
                               atol=1e-6, rtol=1e-5)


@given(st.sampled_from([16, 64, 128]), st.integers(1, 300))
@settings(max_examples=15, deadline=None)
def test_rmsnorm_property_sweep(D, rows):
    x = jax.random.normal(jax.random.PRNGKey(rows), (rows, D))
    scale = jnp.ones((D,))
    out = rmsnorm_forward(x, scale, block_rows=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.reference_rmsnorm(x, scale)),
                               atol=1e-6, rtol=1e-5)


# ------------------------- dispatch wrapper ----------------------------------


def test_ops_dispatch_decode_falls_back():
    """1-token decode (distinct cache positions) must route to the ref path."""
    q = jax.random.normal(jax.random.PRNGKey(6), (1, 1, 2, 32))
    k = jax.random.normal(jax.random.PRNGKey(7), (1, 16, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(8), (1, 16, 2, 32))
    qp = jnp.array([10])
    kp = jnp.arange(16)
    out = ops.flash_attention(q, k, v, q_positions=qp, k_positions=kp,
                              causal=True,
                              kernel=KernelConfig().set(interpret=True))
    expect = ref.reference_attention(q, k, v, q_positions=qp, k_positions=kp,
                                     causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)


def test_attention_layer_flash_impl_matches_ref_impl():
    """End-to-end through the layer: pallas (interpret) == ref backend."""
    from repro.core.module import functional
    from repro.layers import MultiheadAttention

    cfg = MultiheadAttention.default_config().set(
        name="a", input_dim=64, num_heads=4, num_kv_heads=2,
        kernel=KernelConfig().set(backend="pallas", interpret=True))
    layer = cfg.instantiate()
    state = layer.initialize_parameters_recursively(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 128, 64))
    out_flash, _ = functional(layer, state=state, inputs=(x,))
    cfg2 = cfg.clone(kernel=KernelConfig().set(backend="ref"))
    layer2 = cfg2.instantiate()
    out_ref, _ = functional(layer2, state=state, inputs=(x,))
    np.testing.assert_allclose(np.asarray(out_flash), np.asarray(out_ref),
                               atol=2e-5, rtol=2e-5)


# ------------------------------ WKV6 kernel ----------------------------------


def _mk_wkv(key, B, T, H, K, V):
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, T, H, K))
    k = jax.random.normal(ks[1], (B, T, H, K))
    v = jax.random.normal(ks[2], (B, T, H, V))
    w = jax.random.uniform(ks[3], (B, T, H, K), minval=0.55, maxval=0.995)
    u = jax.random.normal(ks[4], (H, K)) * 0.5
    return r, k, v, w, u


@pytest.mark.parametrize("B,T,H,K,V,chunk", [
    (1, 32, 1, 8, 8, 8),
    (2, 64, 2, 16, 16, 16),
    (1, 128, 2, 32, 32, 32),
])
def test_wkv6_kernel_matches_recurrent_oracle(B, T, H, K, V, chunk):
    from repro.kernels.wkv6 import wkv6_forward

    r, k, v, w, u = _mk_wkv(jax.random.PRNGKey(10), B, T, H, K, V)
    out, s = wkv6_forward(r, k, v, w, u, chunk_size=chunk, interpret=True)
    expect, s_ref = ref.reference_wkv6_recurrent(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)


def test_wkv6_kernel_with_initial_state():
    from repro.kernels.wkv6 import wkv6_forward

    B, T, H, K, V = 1, 32, 2, 8, 8
    r, k, v, w, u = _mk_wkv(jax.random.PRNGKey(11), B, T, H, K, V)
    s0 = jax.random.normal(jax.random.PRNGKey(12), (B, H, K, V)).astype(jnp.float32)
    out, s = wkv6_forward(r, k, v, w, u, s0, chunk_size=8, interpret=True)
    expect, s_ref = ref.reference_wkv6_recurrent(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)


def test_wkv6_ragged_falls_back_to_ref():
    from repro.kernels import ops

    B, T, H, K, V = 1, 30, 1, 8, 8  # T not divisible by chunk
    r, k, v, w, u = _mk_wkv(jax.random.PRNGKey(13), B, T, H, K, V)
    out, s = ops.wkv6(r, k, v, w, u,
                      kernel=KernelConfig().set(wkv_chunk_size=8,
                                                interpret=True))
    expect, _ = ref.reference_wkv6_recurrent(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)
