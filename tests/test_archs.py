"""Per-architecture smoke tests (assignment requirement).

Each assigned arch instantiates a REDUCED variant of the same family
(2 layers / 1 block, d_model <= 512, <= 4 experts) and runs one forward +
one train step on CPU, asserting output shapes and no NaNs. Decode-capable
archs also run one serve_step.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.common import SHAPES
from repro.core.module import functional

ARCHS = registry.ASSIGNED_ARCHS


def _smoke_batch(spec, B=2, S=16, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    smoke_cfg = spec.make_smoke()
    vocab = smoke_cfg.decoder.vocab_size
    dim = smoke_cfg.decoder.dim
    if spec.modality == "audio":
        return {
            "input_embeddings": jnp.asarray(
                rng.standard_normal((B, S, dim)), jnp.float32),
            "mask_positions": jnp.asarray(rng.random((B, S)) < 0.3),
            "labels": jnp.asarray(rng.integers(0, vocab, (B, S)), jnp.int32),
        }
    batch = {
        "input_ids": jnp.asarray(rng.integers(0, vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, vocab, (B, S)), jnp.int32),
    }
    if spec.modality == "vlm":
        P = 4
        batch["input_embeddings"] = jnp.asarray(
            rng.standard_normal((B, P, dim)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_shapes(arch):
    spec = registry.get_spec(arch)
    cfg = spec.make_smoke()
    assert cfg.decoder.dim <= 512
    model = cfg.instantiate()
    params = model.initialize_parameters_recursively(jax.random.PRNGKey(0))
    batch = _smoke_batch(spec)
    (loss, aux), col = functional(model, state=params, inputs=(batch,),
                                  is_training=True,
                                  prng_key=jax.random.PRNGKey(1))
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    B, S = batch["labels"].shape
    vocab = cfg.decoder.vocab_size
    assert aux["logits"].shape == (B, S, vocab)
    assert bool(jnp.isfinite(aux["logits"]).all()), f"{arch}: NaN logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """One real optimizer step through the SpmdTrainer substrate."""
    from repro.core.config import config_for_function
    from repro.trainer import optimizers as opt_lib
    from repro.trainer.trainer import SpmdTrainer

    spec = registry.get_spec(arch)
    cfg = SpmdTrainer.default_config().set(
        name="t", model=spec.make_smoke(), max_steps=2, log_every_n=1, seed=0)
    smoke = spec.make_smoke()
    task = {"audio": "audio", "vlm": "vlm"}.get(spec.modality, "lm")
    cfg.input.set(task=task, vocab_size=smoke.decoder.vocab_size, seq_len=16,
                  global_batch_size=2, model_dim=smoke.decoder.dim,
                  num_patches=4)
    cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(peak_lr=1e-3)
    result = cfg.instantiate().run()
    assert np.isfinite(result["final"]["loss"]), f"{arch}: train step NaN"


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if "decode_32k" not in
                                  registry.get_spec(a).skip_shapes])
def test_smoke_serve_step(arch):
    """prefill + one-token decode on the reduced variant."""
    spec = registry.get_spec(arch)
    cfg = spec.make_smoke()
    model = cfg.instantiate()
    params = model.initialize_parameters_recursively(jax.random.PRNGKey(0))
    vocab = cfg.decoder.vocab_size
    B, S = 2, 8
    ids = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, vocab)
    cache, _ = functional(model, state=params, inputs=(B, 32),
                          method="init_states")
    (cache, logits), _ = functional(
        model, state=params, inputs={"state": cache, "input_ids": ids},
        method="prefill")
    assert logits.shape == (B, S, vocab)
    (cache, step_logits), _ = functional(
        model, state=params,
        inputs={"state": cache, "ids_step": ids[:, -1:]},
        method="extend_step")
    assert step_logits.shape == (B, 1, vocab)
    assert bool(jnp.isfinite(step_logits).all()), f"{arch}: NaN decode logits"


def test_registry_covers_assignment():
    assert len(registry.ASSIGNED_ARCHS) == 10
    assert len(registry.SHAPE_NAMES) == 4
    total_pairs = len(registry.supported_pairs()) + len(registry.skipped_pairs())
    assert total_pairs == 40
    # Skips match DESIGN.md §Arch-applicability.
    skipped = {(a, s) for a, s, _ in registry.skipped_pairs()}
    assert ("hubert-xlarge", "decode_32k") in skipped
    assert ("hubert-xlarge", "long_500k") in skipped
    for dense in ["qwen2-1.5b", "qwen1.5-4b", "internlm2-1.8b",
                  "phi-3-vision-4.2b", "arctic-480b"]:
        assert (dense, "long_500k") in skipped
    # Sub-quadratic archs RUN long_500k.
    for a in ["rwkv6-7b", "jamba-1.5-large-398b", "mixtral-8x7b", "gemma2-27b"]:
        assert (a, "long_500k") not in skipped


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_instantiates_and_counts(arch):
    """Full (paper-exact) configs must instantiate structurally (no arrays)
    and report sane param counts."""
    spec = registry.get_spec(arch)
    cfg = spec.make_model()
    total, active = registry.param_counts(cfg)
    expected = {
        "qwen2-1.5b": (1.2e9, 2.2e9),
        "phi-3-vision-4.2b": (3.0e9, 4.9e9),   # decoder only (ViT stubbed)
        "qwen1.5-4b": (3.0e9, 5.2e9),
        "jamba-1.5-large-398b": (330e9, 480e9),
        "mixtral-8x7b": (40e9, 52e9),
        "arctic-480b": (400e9, 530e9),
        "gemma2-27b": (22e9, 32e9),
        "rwkv6-7b": (6e9, 9e9),
        "hubert-xlarge": (0.9e9, 1.3e9),
        "internlm2-1.8b": (1.5e9, 2.3e9),
    }[arch]
    assert expected[0] < total < expected[1], f"{arch}: total={total/1e9:.2f}B"
    assert active <= total


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_match_assigned_shapes(arch):
    spec = registry.get_spec(arch)
    for shape in registry.SHAPE_NAMES:
        if not spec.supports(shape):
            continue
        specs = spec.input_specs(shape)
        info = SHAPES[shape]
        B = info["global_batch"]
        lead = next(iter(specs.values())).shape[0]
        assert lead == B, f"{arch}/{shape}: batch {lead} != {B}"
