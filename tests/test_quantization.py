"""Low-precision end-to-end: the repro.quantization subsystem.

Covers the numerics contract (amax-in-fp32, already-quantized no-op,
round-trip error bounds per format), quantized paged-KV decode parity on
both the ref and (interpreted) Pallas backends, prefix-cache exactness on
shared quantized pages, scale-pool atomicity through every page-moving
manager op, fp8 delayed-scaling train parity vs bf16, the
QuantizationModifier config path, and the grep contract that keeps dtype
branching inside the subsystem.
"""

import pathlib
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.quantization import kv as kvq
from repro.quantization.numerics import dequantize, quantize_int8
from repro.serving import SamplingParams, ServingGateway
from test_serving import _engine, _tiny_lm
from test_trainer import _tiny_trainer_cfg


# ------------------------------- numerics ------------------------------------


def test_quantize_int8_already_quantized_is_noop():
    x = jnp.arange(-4, 4, dtype=jnp.int8).reshape(2, 4)
    q, scale = quantize_int8(x, axis=-1)
    assert q.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(q), np.asarray(x))
    # Unit scales, shaped with the reduced axis kept as 1 (broadcastable).
    assert scale.shape == (2, 1)
    np.testing.assert_array_equal(np.asarray(scale), 1.0)


def test_quantize_int8_amax_in_fp32_for_bf16_inputs():
    # A bf16 tensor whose true amax is not representable in bf16 after
    # in-dtype reduction tricks: the scale must come from an fp32 amax.
    x = (jnp.array([100.0, -100.5, 3.0], jnp.float32)).astype(jnp.bfloat16)
    q, scale = quantize_int8(x, axis=-1)
    assert q.dtype == jnp.int8 and scale.dtype == jnp.float32
    # amax computed in fp32 from the upcast values.
    expect = float(jnp.max(jnp.abs(x.astype(jnp.float32)))) / 127.0
    assert scale.shape == (1,)
    np.testing.assert_allclose(np.asarray(scale), expect, rtol=1e-6)
    deq = dequantize(q, scale)
    # Uniform int8: absolute error is bounded by half a step (amax / 254),
    # plus the bf16 representation error of the inputs themselves.
    step = float(scale[0])
    np.testing.assert_allclose(np.asarray(deq),
                               np.asarray(x, dtype=np.float32),
                               atol=step / 2 + 0.5)


@pytest.mark.parametrize("fmt,rel_bound", [
    (kvq.INT8_KV, 0.01),        # 8 uniform bits: ~1/254 max rel error
    (kvq.FP8_E4M3_KV, 0.07),    # e4m3: 3 mantissa bits, ~2^-4 grid
])
@pytest.mark.parametrize("magnitude", [1e-3, 1.0, 300.0])
def test_kv_write_roundtrip_error_bounds(fmt, rel_bound, magnitude):
    """Per-slot scaled storage keeps relative round-trip error inside the
    format's grid across 5+ decades of input magnitude."""
    key = jax.random.PRNGKey(0)
    k = jax.random.normal(key, (4, 8, 2, 16), jnp.float32) * magnitude
    v = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 2, 16)) * magnitude
    kq, vq, scales = kvq.quantize_kv_write(k, v, fmt)
    assert kq.dtype == fmt.storage_dtype and scales.shape == (4, 8, 2)
    kd, vd = kvq.dequantize_kv(kq, vq, scales.reshape(4 * 8, 2).reshape(4, 8, 2))
    # Error is bounded relative to the per-slot amax (the quantization
    # reference), not per-element values.
    for orig, deq in ((k, kd), (v, vd)):
        amax = jnp.max(jnp.abs(orig), axis=(-2, -1), keepdims=True)
        err = jnp.max(jnp.abs(deq - orig) / amax)
        assert float(err) < rel_bound, (fmt.name, magnitude, float(err))


def test_pool_format_rules():
    assert kvq.pool_format("int8", layout="paged") is kvq.INT8_KV
    assert kvq.pool_format("fp8_e4m3", layout="paged") is kvq.FP8_E4M3_KV
    # fp8 on a dense ring keeps the plain-astype path (no scale rows there).
    assert kvq.pool_format(jnp.float8_e4m3fn, layout="dense") is None
    assert kvq.pool_format(jnp.float32, layout="paged") is None
    with pytest.raises(ValueError, match="paged"):
        kvq.pool_format("int8", layout="dense")


# --------------------- quantized paged decode parity -------------------------


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_int8_paged_decode_token_parity(backend):
    """int8 KV storage (~1% error) must not flip greedy decode tokens vs
    the fp32 paged engine, on both the XLA-gather and Pallas in-kernel
    dequant paths."""
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, 47, size=(2, 12))
    ref = _engine(_tiny_lm("paged", num_pages=25), max_len=32, slots=4)
    toks_ref, _ = ref.generate(prompts, max_new_tokens=10)

    from repro.quantization.modifier import set_kv_cache_dtype

    cfg = _tiny_lm("paged", num_pages=25, decode_backend=backend)
    set_kv_cache_dtype(cfg, "int8", paged_only=True)
    eng = _engine(cfg, max_len=32, slots=4)
    # Storage really is 8-bit (the density claim, not just a dtype tag).
    cache = eng.init_cache()
    k_pools = [l for l in jax.tree_util.tree_leaves(cache)
               if l.dtype == jnp.int8]
    assert k_pools, "no int8 pool leaves allocated"
    toks, _ = eng.generate(prompts, max_new_tokens=10)
    np.testing.assert_array_equal(toks, toks_ref)


@pytest.mark.parametrize("fmt_name,tol", [("int8", 0.02), ("fp8_e4m3", 0.1)])
def test_quantized_paged_kernel_output_close_to_fp32(fmt_name, tol):
    """Kernel-level parity: pallas(interpret) and ref paged decode over a
    quantized pool stay within the format's grid of the fp32 answer."""
    from repro.kernels.flash_decode import paged_flash_decode_forward

    fmt = kvq.format_by_name(fmt_name)
    P, page, Hkv, D, B, N, Hq = 6, 8, 2, 32, 2, 2, 4
    k = jax.random.normal(jax.random.PRNGKey(0), (P, page, Hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(1), (P, page, Hkv, D))
    pos = jnp.tile(jnp.arange(page)[None], (P, 1))
    tbl = jnp.array([[0, 2], [3, -1]], jnp.int32)
    q = jax.random.normal(jax.random.PRNGKey(2), (B, 1, Hq, D))
    qpos = jnp.full((B, 1), 100, jnp.int32)

    o_fp32 = paged_flash_decode_forward(q, k, v, pos, tbl, qpos,
                                        interpret=True)
    kq, vq, scales = kvq.quantize_kv_write(k, v, fmt)
    o_pal = paged_flash_decode_forward(q, kq, vq, pos, tbl, qpos,
                                       scale_pool=scales, interpret=True)
    assert float(jnp.max(jnp.abs(o_pal - o_fp32))) < tol
    o_ref = ops.decode_attention(q, kq, vq, q_positions=qpos, k_positions=pos,
                                 page_tables=tbl, scale_pool=scales)
    assert float(jnp.max(jnp.abs(o_ref - o_fp32))) < tol


def test_int8_requires_paged_layout():
    """Dense rings have no scale rows: the registry rejects int8 there and
    the layer refuses to build the config at all."""
    from repro.kernels.registry import (DEFAULT_CONFIG, KernelDispatchError,
                                        KernelFeatures)
    from repro.kernels import registry as kreg

    feats = KernelFeatures(platform="cpu", dtype="float32", paged=False,
                           kv_dtype="int8")
    cfg_pallas = DEFAULT_CONFIG.clone(
        op_overrides={"attention.decode": "pallas"}, interpret=True)
    with pytest.raises(KernelDispatchError, match="paged"):
        kreg.resolve_backend("attention.decode", feats, cfg_pallas)

    cfg = _tiny_lm()  # dense ring cache
    from repro.quantization.modifier import set_kv_cache_dtype
    with pytest.raises(ValueError, match="paged"):
        set_kv_cache_dtype(cfg, "int8")
        _engine(cfg)


# ------------------- prefix sharing on quantized pages -----------------------


def test_prefix_hit_exact_on_quantized_shared_pages():
    """Quantize-on-write is deterministic, so a prefix hit over int8 pages
    reproduces the cold run's tokens bit-for-bit and still skips prefill."""
    from repro.quantization.modifier import set_kv_cache_dtype

    cfg = _tiny_lm("paged", num_pages=25)
    set_kv_cache_dtype(cfg, "int8", paged_only=True)
    engine = _engine(cfg, max_len=32, slots=4)
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, 47, size=(20,))
    gw = ServingGateway(engine, prefill_chunk=8, seed=0)
    # The quantized pool keeps the full serving feature set.
    assert gw.scheduler.prefix is not None
    assert gw.scheduler.manager.pool_dtype == "int8"
    rid = gw.submit(prompt, sampling=SamplingParams(max_new_tokens=8))
    cold = gw.drain()[rid]
    rid = gw.submit(prompt, sampling=SamplingParams(max_new_tokens=8))
    warm = gw.drain()[rid]
    assert warm.tokens == cold.tokens
    s = gw.scheduler.stats
    assert s["prefix_hits"] == 1 and s["prefill_tokens_skipped"] == 16
    assert gw.scheduler.allocator.num_in_use == 0


def test_spec_decoding_stays_enabled_and_exact_on_int8_pool():
    """scale_pool is inside the attention contract: speculation must stay
    on for quantized pools and greedy spec output must match plain greedy."""
    from repro.quantization.modifier import set_kv_cache_dtype

    cfg = _tiny_lm("paged", num_pages=25)
    set_kv_cache_dtype(cfg, "int8", paged_only=True)
    engine = _engine(cfg, max_len=32, slots=4)
    rng = np.random.default_rng(3)
    prompt = np.tile(rng.integers(1, 47, size=(4,)), 4)  # repetitive: drafts
    gw = ServingGateway(engine, prefill_chunk=8, seed=0, spec_k=3,
                        prefix_caching=False)
    assert gw.scheduler.spec_k == 3, "int8 pool must not disable speculation"
    rid = gw.submit(prompt, sampling=SamplingParams(max_new_tokens=8))
    spec = gw.drain()[rid]
    plain = ServingGateway(engine, prefill_chunk=8, seed=0, spec_k=0,
                           prefix_caching=False)
    rid = plain.submit(prompt, sampling=SamplingParams(max_new_tokens=8))
    assert spec.tokens == plain.drain()[rid].tokens


# --------------------- scale-pool atomicity in the manager -------------------


def test_scale_pool_moves_atomically_with_pages():
    """copy_page / extract_pages / insert_pages / reset_pages must treat
    scale rows exactly like KV payload — bitwise, no leaks."""
    from repro.quantization.modifier import set_kv_cache_dtype

    from repro.serving import Scheduler

    cfg = _tiny_lm("paged", num_pages=9, page=4)
    set_kv_cache_dtype(cfg, "int8", paged_only=True)
    engine = _engine(cfg, max_len=16, slots=2)
    sched = Scheduler(engine, prefill_chunk=4, spec_k=0)
    mgr, cache = sched.manager, sched._cache
    names = {i.name for i in mgr._info}
    assert "scale_pool" in names and mgr.pool_dtype == "int8"

    # Cache leaves are scan-stacked (leading layer axis), so page indexing
    # must go through each leaf's page_axis, like the manager itself does.
    def pages(c, leaf_name, idx):
        leaves = jax.tree_util.tree_flatten(c)[0]
        out = []
        for leaf, info in zip(leaves, mgr._info):
            if info.name == leaf_name:
                out.append(np.take(np.asarray(leaf), idx,
                                   axis=info.page_axis))
        assert out, leaf_name
        return out

    # Write distinctive scales into pages 1..3 of every scale_pool leaf.
    def poke(c):
        leaves = jax.tree_util.tree_flatten(c)[0]
        treedef = jax.tree_util.tree_structure(c)
        out = []
        for i, (leaf, info) in enumerate(zip(leaves, mgr._info)):
            if info.name == "scale_pool":
                moved = jnp.moveaxis(leaf, info.page_axis, 0)
                stamp = (jnp.arange(moved[1:4].size, dtype=leaf.dtype)
                         .reshape(moved[1:4].shape) + 2.0 + i)
                leaf = jnp.moveaxis(moved.at[1:4].set(stamp), 0,
                                    info.page_axis)
            out.append(leaf)
        return jax.tree_util.tree_unflatten(treedef, out)

    cache = poke(cache)
    before_scales = pages(cache, "scale_pool", [1, 2, 3])
    before_k = pages(cache, "k_pool", [1, 2, 3])
    before_v = pages(cache, "v_pool", [1, 2, 3])

    # copy_page (the COW fork): scale rows travel with the payload.
    copied = mgr.copy_page(cache, src=2, dst=5, valid=4)
    for got, want in zip(pages(copied, "scale_pool", [5]),
                         pages(cache, "scale_pool", [2])):
        np.testing.assert_array_equal(got, want)

    # extract -> reset -> insert round-trips bitwise into new physical pages.
    host = mgr.extract_pages(cache, [1, 2, 3])
    wiped = mgr.reset_pages(cache, [1, 2, 3])
    for leaf in pages(wiped, "pos_pool", [1, 2, 3]):
        # reset invalidates recycled pages' positions; stale scale (and KV)
        # rows become unreachable through the mask — same contract as KV.
        np.testing.assert_array_equal(leaf, -np.ones_like(leaf))
    restored = mgr.insert_pages(wiped, [6, 7, 8], host)
    for got, want in zip(pages(restored, "scale_pool", [6, 7, 8]),
                         before_scales):
        np.testing.assert_array_equal(got, want)
    # KV payload moved with the same indices (atomicity).
    for got, want in zip(pages(restored, "k_pool", [6, 7, 8]), before_k):
        np.testing.assert_array_equal(got, want)
    for got, want in zip(pages(restored, "v_pool", [6, 7, 8]), before_v):
        np.testing.assert_array_equal(got, want)


def test_evict_restore_roundtrip_on_quantized_pool():
    """End-to-end leak guard: preemption under pool pressure extracts and
    reinserts quantized pages (scales included) and every request still
    matches the uncontended dense run."""
    from repro.quantization.modifier import set_kv_cache_dtype
    from repro.serving import Scheduler, ServeRequest

    cfg = _tiny_lm("paged", num_pages=1 + 4, page=4)
    set_kv_cache_dtype(cfg, "int8", paged_only=True)
    engine = _engine(cfg, max_len=16, slots=2)
    dense = _engine(_tiny_lm(), max_len=16, slots=2)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, 47, size=(6,)) for _ in range(3)]

    sched = Scheduler(engine, prefill_chunk=4, spec_k=0)
    for rid, prompt in enumerate(prompts):
        sched.submit(ServeRequest(request_id=rid, prompt=prompt,
                                  max_new_tokens=8, priority=rid,
                                  arrival_time=0.1 * rid))
    while sched.step():
        pass
    assert sched.stats["preemptions"] > 0, "pool contention never triggered"
    for rid, prompt in enumerate(prompts):
        expect, _ = dense.generate(prompt[None, :], max_new_tokens=8)
        np.testing.assert_array_equal(
            np.asarray(sched.result(rid).tokens), expect[0],
            err_msg=f"request {rid} diverged after eviction on int8 pool")
    assert sched.allocator.num_in_use == 0


# ----------------------------- fp8 training ----------------------------------


def _bf16_cfg(steps):
    from repro.layers.base import bf16_policy
    from repro.trainer.mesh_rules import DtypePolicyModifier

    cfg = _tiny_trainer_cfg(steps=steps)
    return DtypePolicyModifier.default_config().set(
        policy=bf16_policy()).instantiate().apply(cfg)


def test_fp8_train_parity_60_steps():
    """Delayed-scaling fp8 boundaries track the bf16 loss curve within 1%
    relative at 60 steps (the acceptance bound), with fp32 amax histories
    advancing in the (scan-stacked) layer state."""
    from repro.quantization.modifier import QuantizationModifier

    r16 = _bf16_cfg(60).instantiate().run()
    cfg8 = QuantizationModifier.default_config().set(
        fp8=True).instantiate().apply(_bf16_cfg(60))
    r8 = cfg8.instantiate().run()
    l16, l8 = r16["final"]["loss"], r8["final"]["loss"]
    rel = abs(l8 - l16) / l16
    assert rel < 0.01, (l16, l8, rel)
    assert l8 < r8["history"][0]["loss"] * 0.8, "fp8 run did not learn"

    hists = [(p, v) for p, v in _walk(r8["state"]["params"]).items()
             if p.endswith("fp8_amax_history")]
    assert hists, "no amax history params were created"
    for path, v in hists:
        assert v.dtype == jnp.float32, path  # pinned through bf16 policy
        assert float(jnp.max(v)) > 0, f"history never advanced: {path}"


def test_fp8_composes_with_grad_accum():
    """Microbatched fp8: per-microbatch amaxes max-combine (amax semantics)
    and the step still applies one history roll."""
    from repro.quantization.modifier import QuantizationModifier

    cfg = QuantizationModifier.default_config().set(
        fp8=True).instantiate().apply(_bf16_cfg(6))
    cfg.grad_accum_steps = 2
    res = cfg.instantiate().run()
    assert np.isfinite(res["final"]["loss"])
    hists = [v for p, v in _walk(res["state"]["params"]).items()
             if p.endswith("fp8_amax_history")]
    assert hists and all(float(jnp.max(v)) > 0 for v in hists)


def test_state_update_max_combine_under_accum():
    """apply_state_updates folds collected amaxes into params; the accum
    scan combines microbatch updates with max, not mean."""
    from repro.trainer.train_step import apply_state_updates

    params = {"a": {"h": jnp.zeros(3)}, "w": jnp.ones(2)}
    out = apply_state_updates(params, {"a/h": jnp.arange(3.0)})
    np.testing.assert_array_equal(np.asarray(out["a"]["h"]), [0, 1, 2])
    np.testing.assert_array_equal(np.asarray(out["w"]), 1.0)  # untouched
    with pytest.raises(KeyError):
        apply_state_updates(params, {"a/missing": jnp.zeros(1)})


def _walk(d, pre=""):
    out = {}
    for k, v in d.items():
        if isinstance(v, dict):
            out.update(_walk(v, pre + k + "/"))
        else:
            out[pre + k] = v
    return out


# -------------------------- modifier config path -----------------------------


def test_quantization_modifier_w8a8_and_kv_dtype():
    """One modifier flips Linears to QuantizedLinear AND retargets paged
    KV storage — per-arch enablement is pure config."""
    from repro.quantization.modifier import QuantizationModifier

    cfg = _tiny_trainer_cfg(steps=1)
    # Dense model: kv_dtype with paged_only leaves the ring cache alone.
    mod = QuantizationModifier.default_config().set(
        w8a8=True, kv_dtype="int8").instantiate()
    cfg = mod.apply(cfg)

    from repro.core.config import visit_config

    kinds = []
    visit_config(cfg, lambda p, c: kinds.append(type(c).__qualname__))
    assert not any(k == "Linear.Config" for k in kinds), "a Linear survived"
    assert any("QuantizedLinear" in k for k in kinds)
    # Dense attention cfg untouched by the paged-only kv retarget (a dense
    # ring has nowhere to carry scale rows).
    assert cfg.model.decoder.stack.layer.self_attention.kv_cache_dtype \
        is not jnp.int8


def test_fp8_boundary_only_on_linear():
    """The fp8 fake-quant hook fires at Linear boundaries only; the base
    layer and QuantizedLinear (already int8) opt out."""
    from repro.layers.base import BaseLayer
    from repro.layers.basic import Linear
    from repro.quantization.linear import QuantizedLinear

    assert Linear._fp8_boundary is True
    assert BaseLayer._fp8_boundary is False
    assert QuantizedLinear._fp8_boundary is False


# ------------------------------ grep contract --------------------------------


def test_no_dtype_branching_outside_quantization():
    """Low-precision storage dtypes are named ONLY inside the quantization
    subsystem, the memopt subsystem (optimizer *state* dtypes — same
    containment rule, see tests/test_memopt.py for its own contract), and
    the kernel registry's capability tables. Everything else must thread
    precision through config (DtypePolicy / kv_cache_dtype / KVQuantFormat /
    state_dtype), never branch on dtype literals."""
    src = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"
    # Dtype spellings only: short *format names* ("int8", "fp8_e4m3")
    # passed to the subsystem's own entry points are the sanctioned API,
    # so string-equality branching on them is what the pattern hunts
    # (`== "int8"`), not the names themselves.
    pattern = re.compile(
        r"jnp\.int8|jnp\.float8|float8_e4m3fn|float8_e5m2"
        r"|==\s*[\"'](?:int8|fp8|float8)|dtype\s*==\s*[\"']")
    allowed = {"quantization", "kernels/registry.py"}
    offenders = []
    for path in sorted(src.rglob("*.py")):
        rel = path.relative_to(src).as_posix()
        if (rel.startswith("quantization/") or rel.startswith("memopt/")
                or rel in allowed):
            continue
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            if pattern.search(line):
                offenders.append(f"{rel}:{lineno}: {line.strip()}")
    assert not offenders, (
        "dtype literals escaped the quantization subsystem:\n"
        + "\n".join(offenders))
