"""Trainer substrate tests: overfit, grad accum, checkpoint, mesh rules."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.config import config_for_function
from repro.layers import CausalLM, Decoder, Repeat, TransformerLayer
from repro.trainer import optimizers as opt_lib
from repro.trainer.mesh_rules import (
    AttentionImplModifier,
    GradAccumModifier,
    MeshShapeModifier,
    RematPolicyModifier,
    apply_mesh_rules,
)
from repro.trainer.trainer import SpmdTrainer


def _tiny_trainer_cfg(tmpdir=None, vocab=32, dim=32, L=2, steps=30,
                      batch=8, seq=16):
    layer = TransformerLayer.default_config().set(input_dim=dim)
    layer.self_attention.set(num_heads=4, num_kv_heads=2, impl="ref")
    layer.feed_forward.set(hidden_dim=dim * 2)
    model = CausalLM.default_config().set(
        decoder=Decoder.default_config().set(
            vocab_size=vocab, dim=dim,
            stack=Repeat.default_config().set(layer=layer, num_layers=L,
                                              remat_policy=None)))
    cfg = SpmdTrainer.default_config().set(name="trainer", model=model,
                                           max_steps=steps, log_every_n=5, seed=1)
    cfg.input.set(task="lm", vocab_size=vocab, seq_len=seq, global_batch_size=batch)
    cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(
        peak_lr=1e-2, weight_decay=1e-4)
    if tmpdir is not None:
        cfg.checkpointer = Checkpointer.default_config().set(directory=str(tmpdir))
        cfg.checkpoint_every_n = 10
    return cfg


def test_overfit_tiny_lm():
    """Loss must drop substantially on the learnable synthetic stream."""
    cfg = _tiny_trainer_cfg(steps=100)
    trainer = cfg.instantiate()
    result = trainer.run()
    first = result["history"][0]["loss"]
    last = result["final"]["loss"]
    assert np.isfinite(last)
    assert last < first * 0.75, f"no learning: {first} -> {last}"


def test_grad_accum_equivalence():
    """k microbatches of B/k == one batch of B (same grads => same params)."""
    cfg_a = _tiny_trainer_cfg(steps=3, batch=8)
    cfg_b = _tiny_trainer_cfg(steps=3, batch=8)
    cfg_b.grad_accum_steps = 2
    ra = cfg_a.instantiate().run()
    rb = cfg_b.instantiate().run()
    la = jax.tree.leaves(ra["state"]["params"])
    lb = jax.tree.leaves(rb["state"]["params"])
    for a, b in zip(la, lb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_checkpoint_roundtrip_and_gc(tmp_path):
    cfg = _tiny_trainer_cfg(tmpdir=tmp_path, steps=30)
    cfg.checkpointer.keep_last_n = 2
    trainer = cfg.instantiate()
    result = trainer.run()
    ckpt = trainer.checkpointer
    ckpt.wait()
    assert ckpt.latest_step() == 30
    # GC kept only last 2
    step_dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(step_dirs) == 2
    restored = ckpt.restore(like=jax.device_get(result["state"]))
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(result["state"]["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_from_checkpoint(tmp_path):
    cfg = _tiny_trainer_cfg(tmpdir=tmp_path, steps=20)
    t1 = cfg.instantiate()
    t1.run(num_steps=10)
    t1.checkpointer.wait()
    assert t1.checkpointer.latest_step() == 10
    # New trainer resumes from step 10 and continues to 20.
    t2 = cfg.clone().instantiate()
    result = t2.run(num_steps=20)
    assert result["final"]["step"] == 19
    assert int(result["state"]["step"]) == 20


def test_state_shardings_structure():
    cfg = _tiny_trainer_cfg(steps=1)
    trainer = cfg.instantiate()
    state = trainer.init_state()
    shardings = trainer.state_shardings(jax.eval_shape(lambda: state))
    # Same tree structure.
    assert jax.tree.structure(jax.tree.map(lambda x: 0, state)) == \
        jax.tree.structure(jax.tree.map(lambda x: 0, shardings))


def test_mesh_rules_apply_per_target():
    """Paper App. A: per-target config with zero model-code changes."""
    cfg = _tiny_trainer_cfg(steps=1)
    rules = [
        ("tpu-v5e-.*", [
            MeshShapeModifier.default_config().set(
                mesh_shape=(16, 16), mesh_axis_names=("data", "model")),
            RematPolicyModifier.default_config().set(policy="full"),
            AttentionImplModifier.default_config().set(impl="flash"),
        ]),
        ("cpu-.*", [
            MeshShapeModifier.default_config().set(
                mesh_shape=(1,), mesh_axis_names=("data",)),
            AttentionImplModifier.default_config().set(
                impl="ref", kernel_interpret=True),
            GradAccumModifier.default_config().set(steps=4),
        ]),
    ]
    tpu_cfg = apply_mesh_rules(cfg.clone(), instance_type="tpu-v5e-256-4", rules=rules)
    assert tpu_cfg.mesh_shape == (16, 16)
    assert tpu_cfg.model.decoder.stack.layer.self_attention.impl == "flash"
    assert tpu_cfg.model.decoder.stack.remat_policy == "full"

    cpu_cfg = apply_mesh_rules(cfg.clone(), instance_type="cpu-local", rules=rules)
    assert cpu_cfg.mesh_shape == (1,)
    assert cpu_cfg.grad_accum_steps == 4
    assert cpu_cfg.model.decoder.stack.layer.self_attention.impl == "ref"


def test_optimizer_unit_behaviour():
    params = {"w": jnp.ones((4,)), "b": jnp.zeros((2,))}
    grads = {"w": jnp.full((4,), 2.0), "b": jnp.ones((2,))}
    tx = opt_lib.adamw(peak_lr=0.1, weight_decay=0.0, max_grad_norm=None)
    state = tx.init(params)
    updates, state = tx.update(grads, state, params)
    # First Adam step: update = -lr * sign-ish(grad).
    np.testing.assert_allclose(np.asarray(updates["w"]),
                               -0.1 * np.ones(4), rtol=1e-3)


def test_clip_by_global_norm():
    grads = {"w": jnp.full((4,), 10.0)}
    tx = opt_lib.clip_by_global_norm(1.0)
    out, _ = tx.update(grads, tx.init(grads), None)
    np.testing.assert_allclose(float(opt_lib.global_norm(out)), 1.0, rtol=1e-5)


def test_lr_schedule_shape():
    sched = opt_lib.linear_warmup_cosine(peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(sched(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(sched(jnp.asarray(10))), 1.0, rtol=1e-5)
    assert float(sched(jnp.asarray(100))) < 0.15
    assert float(sched(jnp.asarray(5))) == pytest.approx(0.5, rel=1e-5)
