"""Trainer substrate tests: overfit, grad accum, checkpoint, mesh rules,
dtype policy (mixed precision), ZeRO-1 optimizer-state sharding."""

import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.config import config_for_function
from repro.layers import (
    CausalLM,
    Decoder,
    DtypePolicy,
    Repeat,
    TransformerLayer,
    bf16_policy,
)
from repro.trainer import optimizers as opt_lib
from repro.kernels.registry import KernelConfig
from repro.trainer.mesh_rules import (
    DtypePolicyModifier,
    GradAccumModifier,
    KernelModifier,
    MeshShapeModifier,
    OffloadOptimizerModifier,
    RematPolicyModifier,
    Zero1Modifier,
    apply_mesh_rules,
)
from repro.trainer.trainer import SpmdTrainer, WatchdogTimeout, _Watchdog


def _tiny_trainer_cfg(tmpdir=None, vocab=32, dim=32, L=2, steps=30,
                      batch=8, seq=16):
    layer = TransformerLayer.default_config().set(input_dim=dim)
    layer.self_attention.set(num_heads=4, num_kv_heads=2)
    layer.feed_forward.set(hidden_dim=dim * 2)
    model = CausalLM.default_config().set(
        decoder=Decoder.default_config().set(
            vocab_size=vocab, dim=dim,
            stack=Repeat.default_config().set(layer=layer, num_layers=L,
                                              remat_policy=None)))
    cfg = SpmdTrainer.default_config().set(name="trainer", model=model,
                                           max_steps=steps, log_every_n=5, seed=1)
    cfg.input.set(task="lm", vocab_size=vocab, seq_len=seq, global_batch_size=batch)
    cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(
        peak_lr=1e-2, weight_decay=1e-4)
    if tmpdir is not None:
        cfg.checkpointer = Checkpointer.default_config().set(directory=str(tmpdir))
        cfg.checkpoint_every_n = 10
    return cfg


def test_overfit_tiny_lm():
    """Loss must drop substantially on the learnable synthetic stream."""
    cfg = _tiny_trainer_cfg(steps=100)
    trainer = cfg.instantiate()
    result = trainer.run()
    first = result["history"][0]["loss"]
    last = result["final"]["loss"]
    assert np.isfinite(last)
    assert last < first * 0.75, f"no learning: {first} -> {last}"


def test_grad_accum_equivalence():
    """k microbatches of B/k == one batch of B (same grads => same params)."""
    cfg_a = _tiny_trainer_cfg(steps=3, batch=8)
    cfg_b = _tiny_trainer_cfg(steps=3, batch=8)
    cfg_b.grad_accum_steps = 2
    ra = cfg_a.instantiate().run()
    rb = cfg_b.instantiate().run()
    la = jax.tree.leaves(ra["state"]["params"])
    lb = jax.tree.leaves(rb["state"]["params"])
    for a, b in zip(la, lb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_checkpoint_roundtrip_and_gc(tmp_path):
    cfg = _tiny_trainer_cfg(tmpdir=tmp_path, steps=30)
    cfg.checkpointer.keep_last_n = 2
    trainer = cfg.instantiate()
    result = trainer.run()
    ckpt = trainer.checkpointer
    ckpt.wait()
    assert ckpt.latest_step() == 30
    # GC kept only last 2
    step_dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(step_dirs) == 2
    restored = ckpt.restore(like=jax.device_get(result["state"]))
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(result["state"]["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_from_checkpoint(tmp_path):
    cfg = _tiny_trainer_cfg(tmpdir=tmp_path, steps=20)
    t1 = cfg.instantiate()
    t1.run(num_steps=10)
    t1.checkpointer.wait()
    assert t1.checkpointer.latest_step() == 10
    # New trainer resumes from step 10 and continues to 20.
    t2 = cfg.clone().instantiate()
    result = t2.run(num_steps=20)
    assert result["final"]["step"] == 19
    assert int(result["state"]["step"]) == 20


def test_state_shardings_structure():
    cfg = _tiny_trainer_cfg(steps=1)
    trainer = cfg.instantiate()
    state = trainer.init_state()
    shardings = trainer.state_shardings(jax.eval_shape(lambda: state))
    # Same tree structure.
    assert jax.tree.structure(jax.tree.map(lambda x: 0, state)) == \
        jax.tree.structure(jax.tree.map(lambda x: 0, shardings))


def test_mesh_rules_apply_per_target():
    """Paper App. A: per-target config with zero model-code changes."""
    cfg = _tiny_trainer_cfg(steps=1)
    rules = [
        ("tpu-v5e-.*", [
            MeshShapeModifier.default_config().set(
                mesh_shape=(16, 16), mesh_axis_names=("data", "model")),
            RematPolicyModifier.default_config().set(policy="full"),
            KernelModifier.default_config().set(
                op_overrides={"attention.fwd": "pallas"}),
        ]),
        ("cpu-.*", [
            MeshShapeModifier.default_config().set(
                mesh_shape=(1,), mesh_axis_names=("data",)),
            KernelModifier.default_config().set(backend="ref",
                                                interpret=True),
            GradAccumModifier.default_config().set(steps=4),
        ]),
    ]
    tpu_cfg = apply_mesh_rules(cfg.clone(), instance_type="tpu-v5e-256-4", rules=rules)
    assert tpu_cfg.mesh_shape == (16, 16)
    attn_kernel = tpu_cfg.model.decoder.stack.layer.self_attention.kernel
    assert attn_kernel.op_overrides == {"attention.fwd": "pallas"}
    assert tpu_cfg.model.decoder.stack.remat_policy == "full"
    # The one KernelModifier reaches EVERY KernelConfig in the tree, not
    # just attention (rmsnorm/wkv6-calling layers included).
    norm_kernel = tpu_cfg.model.decoder.stack.layer.norm.kernel
    assert norm_kernel.op_overrides == {"attention.fwd": "pallas"}

    cpu_cfg = apply_mesh_rules(cfg.clone(), instance_type="cpu-local", rules=rules)
    assert cpu_cfg.mesh_shape == (1,)
    assert cpu_cfg.grad_accum_steps == 4
    attn_kernel = cpu_cfg.model.decoder.stack.layer.self_attention.kernel
    assert attn_kernel.backend == "ref" and attn_kernel.interpret is True


def test_mesh_rules_modifiers_offload_kernelblock_zero1():
    """Satellite coverage: the remaining one-knob modifiers + the generic
    KernelModifier tiling table."""
    cfg = _tiny_trainer_cfg(steps=1)
    rules = [
        ("tpu-.*", [
            OffloadOptimizerModifier.default_config().set(enabled=True),
            KernelModifier.default_config().set(
                update={"blockwise_chunk_size": 256, "block_q": 512}),
            Zero1Modifier.default_config(),
            GradAccumModifier.default_config().set(steps=2),
        ]),
    ]
    out = apply_mesh_rules(cfg.clone(), instance_type="tpu-v5e-16", rules=rules)
    assert out.offload_optimizer_state is True
    assert out.opt_state_sharding == "zero1"
    assert out.grad_accum_steps == 2
    attn = out.model.decoder.stack.layer.self_attention
    assert attn.kernel.blockwise_chunk_size == 256
    assert attn.kernel.block_q == 512
    # Non-matching instance types leave the config untouched.
    same = apply_mesh_rules(cfg.clone(), instance_type="gpu-H100", rules=rules)
    assert same.opt_state_sharding == "params"
    # Unknown tiling keys fail loudly instead of silently no-opping.
    bad = [("tpu-.*", [KernelModifier.default_config().set(
        update={"blockwzse_chunk": 1})])]
    with pytest.raises(ValueError, match="non-KernelConfig fields"):
        apply_mesh_rules(cfg.clone(), instance_type="tpu-v5e-16", rules=bad)


def test_mesh_rules_fullmatch_not_prefix():
    """Regression (satellite): rules are anchored fullmatch. The old
    ``fullmatch(...) or match(...)`` made every rule a prefix match, so a
    broad rule listed first (e.g. "tpu-.*") shadowed "tpu-v5e-.*" AND a
    non-.* pattern like "tpu-v5e" matched "tpu-v5e-256"."""
    cfg = _tiny_trainer_cfg(steps=1)
    rules = [
        # A pattern without .* must NOT prefix-match longer instance types.
        ("tpu-v5e", [GradAccumModifier.default_config().set(steps=8)]),
        ("tpu-v5e-.*", [GradAccumModifier.default_config().set(steps=2)]),
    ]
    out = apply_mesh_rules(cfg.clone(), instance_type="tpu-v5e-256", rules=rules)
    assert out.grad_accum_steps == 2, \
        "bare 'tpu-v5e' prefix-matched 'tpu-v5e-256'"
    exact = apply_mesh_rules(cfg.clone(), instance_type="tpu-v5e", rules=rules)
    assert exact.grad_accum_steps == 8


def test_dtype_policy_modifier_reaches_every_layer():
    """The paper's ~10-LoC claim for mixed precision: ONE modifier sets the
    policy on every layer config in the tree, and the trainer grad dtype."""
    cfg = _tiny_trainer_cfg(steps=1)
    policy = DtypePolicy().set(compute_dtype=jnp.bfloat16,
                               grad_dtype=jnp.bfloat16)
    mod = DtypePolicyModifier.default_config().set(policy=policy).instantiate()
    cfg = mod.apply(cfg)
    dec = cfg.model.decoder
    for node in (cfg.model, dec, dec.emb, dec.stack, dec.stack.layer,
                 dec.stack.layer.self_attention,
                 dec.stack.layer.self_attention.proj,
                 dec.stack.layer.feed_forward, dec.stack.layer.norm):
        assert node.dtype_policy is not None, node
        assert node.dtype_policy.compute_dtype == jnp.bfloat16
    assert cfg.grad_dtype == jnp.bfloat16


def test_bf16_policy_training_parity():
    """bf16-compute/fp32-master training must track the fp32 loss curve
    (documented tolerance: final loss within 5% after 60 steps) while the
    model actually computes in bf16 (logits dtype check)."""
    from repro.core.module import functional

    def run(policy):
        cfg = _tiny_trainer_cfg(steps=60)
        if policy is not None:
            mod = DtypePolicyModifier.default_config().set(
                policy=policy).instantiate()
            cfg = mod.apply(cfg)
        trainer = cfg.instantiate()
        return trainer, trainer.run()

    _, r32 = run(None)
    tr16, r16 = run(bf16_policy())
    assert all(str(l.dtype) == "float32"
               for l in jax.tree.leaves(r16["state"]["params"]))
    logits, _ = functional(tr16.model, state=jax.device_get(r16["state"]["params"]),
                           inputs=(tr16.input.make_batch(0),), method="predict")
    assert logits.dtype == jnp.bfloat16
    rel = abs(r16["final"]["loss"] - r32["final"]["loss"]) / r32["final"]["loss"]
    assert rel < 0.05, (r32["final"]["loss"], r16["final"]["loss"])
    # Both actually learned.
    assert r16["final"]["loss"] < r16["history"][0]["loss"] * 0.8


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "jamba-1.5-large-398b",
                                  "hubert-xlarge"])
def test_bf16_policy_traces_on_diverse_archs(arch):
    """One DtypePolicyModifier must cover MoE routing, hybrid Mamba blocks
    and the audio MaskedLM without touching any model code: trace the full
    train step (eval_shape, no compile) under the bf16 policy."""
    from repro.configs import registry

    spec = registry.get_spec(arch)
    model_cfg = spec.make_smoke()
    cfg = SpmdTrainer.default_config().set(name="t", model=model_cfg,
                                           max_steps=1)
    task = {"audio": "audio", "vlm": "vlm"}.get(spec.modality, "lm")
    cfg.input.set(task=task, vocab_size=model_cfg.decoder.vocab_size,
                  seq_len=16, global_batch_size=4,
                  model_dim=model_cfg.decoder.dim, num_patches=4)
    cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(peak_lr=1e-3)
    mod = DtypePolicyModifier.default_config().set(
        policy=bf16_policy()).instantiate()
    cfg = mod.apply(cfg)
    trainer = cfg.instantiate()
    state = jax.eval_shape(trainer.init_state)
    batch = {k: jnp.asarray(v) for k, v in trainer.input.make_batch(0).items()}
    new_state, metrics = jax.eval_shape(trainer.make_train_step(), state, batch)
    assert metrics["loss"].dtype == jnp.float32  # loss stays an fp32 island
    # Master params remain fp32 through the update.
    assert all(l.dtype == jnp.float32
               for l in jax.tree.leaves(new_state["params"]))


def test_grad_accum_rejects_indivisible_batch():
    cfg = _tiny_trainer_cfg(steps=1, batch=8)
    cfg.grad_accum_steps = 3  # 8 % 3 != 0
    with pytest.raises(ValueError, match="not divisible by grad_accum_steps"):
        cfg.instantiate().run()


def test_grad_accum_passes_non_array_entries_through():
    """Shared (non-batched) entries like position arrays or python scalars
    must not be microbatch-split (the old code crashed on .reshape)."""
    cfg = _tiny_trainer_cfg(steps=2, batch=8)
    cfg.grad_accum_steps = 2
    trainer = cfg.instantiate()

    step_fn = trainer.make_train_step()
    state = trainer.init_state()
    batch = {k: jnp.asarray(v) for k, v in trainer.input.make_batch(0).items()}
    batch["positions"] = jnp.arange(batch["input_ids"].shape[1])  # (S,) shared
    new_state, metrics = jax.jit(step_fn)(state, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_grad_accum_accumulates_in_configured_dtype():
    cfg = _tiny_trainer_cfg(steps=1, batch=8)
    cfg.grad_accum_steps = 2
    cfg.grad_dtype = jnp.bfloat16
    trainer = cfg.instantiate()
    # Trace the step: the scan carry (accumulated grads) must be bf16.
    from repro.trainer.train_step import make_grad_fn, make_loss_fn

    loss_fn = make_loss_fn(trainer.model)
    grad_fn = make_grad_fn(loss_fn, grad_accum_steps=2,
                           grad_dtype=jnp.bfloat16)
    state = trainer.init_state()
    batch = {k: jnp.asarray(v) for k, v in trainer.input.make_batch(0).items()}
    _, _, grads = jax.eval_shape(
        grad_fn, state["params"], batch, jax.random.PRNGKey(0))
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(grads))


def test_watchdog_warn_and_raise_modes():
    # warn: records, never raises, never interrupts.
    wd = _Watchdog(0.02, on_timeout="warn")
    wd.beat(0)
    time.sleep(0.08)
    wd.stop()
    assert wd.fired == [0]
    # raise: the timer thread interrupts the (hung) main thread, and
    # check() converts the interrupt into the typed error — this is how a
    # hard-blocked host loop actually surfaces the timeout.
    wd = _Watchdog(0.02, on_timeout="raise")
    interrupted = False
    try:
        wd.beat(3)
        for _ in range(200):  # a "hung step": blocked in the host loop
            time.sleep(0.01)
    except KeyboardInterrupt:
        interrupted = True
    assert interrupted and wd.fired == [3]
    with pytest.raises(WatchdogTimeout, match=r"\[3\]"):
        wd.check()
    with pytest.raises(WatchdogTimeout):  # heartbeat fallback also raises
        wd.beat(4)
    with pytest.raises(ValueError, match="on_timeout"):
        _Watchdog(1.0, on_timeout="explode")


def test_train_step_compiles_once_across_resume(tmp_path):
    """Compile-count regression guard: one trainer instance compiles the
    train step exactly once, including a checkpoint-resume continuation."""
    cfg = _tiny_trainer_cfg(tmpdir=tmp_path, steps=20)
    trainer = cfg.instantiate()
    trainer.run(num_steps=10)
    trainer.checkpointer.wait()
    assert trainer.checkpointer.latest_step() == 10
    result = trainer.run(num_steps=20)  # resumes from step 10
    assert int(result["state"]["step"]) == 20
    assert trainer._jit_step._cache_size() == 1, \
        "train step recompiled across checkpoint resume"


ZERO1_SUBPROCESS = textwrap.dedent("""
    import jax, numpy as np
    from repro.core.config import config_for_function, update_configs_recursively
    from repro.layers import CausalLM, Decoder, Repeat, TransformerLayer
    from repro.trainer import optimizers as opt_lib
    from repro.trainer.trainer import SpmdTrainer

    PART_FIELDS = ["weight_partition", "qkv_weight_partition",
                   "out_weight_partition", "up_weight_partition",
                   "down_weight_partition", "gate_weight_partition"]

    def make(zero1):
        layer = TransformerLayer.default_config().set(input_dim=32)
        layer.self_attention.set(num_heads=4, num_kv_heads=2)
        layer.feed_forward.set(hidden_dim=64)
        model = CausalLM.default_config().set(
            decoder=Decoder.default_config().set(
                vocab_size=32, dim=32,
                stack=Repeat.default_config().set(
                    layer=layer, num_layers=2, remat_policy=None)))
        cfg = SpmdTrainer.default_config().set(
            name="t", model=model, max_steps=2, log_every_n=1, seed=1,
            mesh_shape=(4,), mesh_axis_names=("data",))
        # Pure data parallelism: weights replicated along "data".
        update_configs_recursively(cfg.model, {f: None for f in PART_FIELDS})
        cfg.input.set(task="lm", vocab_size=32, seq_len=16, global_batch_size=8)
        cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(peak_lr=1e-2)
        if zero1:
            cfg.opt_state_sharding = "zero1"
        return cfg

    def per_device_opt_bytes(state, shardings):
        total = 0
        for leaf, sh in zip(jax.tree.leaves(state["opt_state"]),
                            jax.tree.leaves(shardings["opt_state"])):
            total += int(np.prod(sh.shard_shape(leaf.shape))) * leaf.dtype.itemsize
        return total

    out = {}
    for zero1 in (False, True):
        trainer = make(zero1).instantiate()
        res = trainer.run()
        state = res["state"]
        shardings = trainer.state_shardings(jax.eval_shape(lambda: state))
        # Every opt-state leaf must actually LIVE in the declared layout.
        for leaf, sh in zip(jax.tree.leaves(state["opt_state"]),
                            jax.tree.leaves(shardings["opt_state"])):
            assert leaf.sharding == sh, (leaf.shape, leaf.sharding, sh)
        out[zero1] = (per_device_opt_bytes(state, shardings),
                      float(res["final"]["loss"]))
    ratio = out[False][0] / out[True][0]
    assert ratio > 3.0, f"ZeRO-1 saved only {ratio:.2f}x on a 4-way mesh"
    assert abs(out[False][1] - out[True][1]) < 1e-4, out

    # Regression: zero1 with the DEFAULT (FSDP-style, data-axis-using)
    # weight partitions must not produce duplicate-axis PartitionSpecs.
    cfg = make(True)
    layer = TransformerLayer.default_config().set(input_dim=32)
    layer.self_attention.set(num_heads=4, num_kv_heads=2)
    layer.feed_forward.set(hidden_dim=64)
    cfg.model = CausalLM.default_config().set(
        name="model",
        decoder=Decoder.default_config().set(
            vocab_size=32, dim=32,
            stack=Repeat.default_config().set(
                layer=layer, num_layers=2, remat_policy=None)))
    res = cfg.instantiate().run()
    assert np.isfinite(res["final"]["loss"])
    print(f"OK ratio={ratio:.3f}")
""")


def test_zero1_shards_opt_state_on_multidevice_mesh():
    """Per-device optimizer-state bytes shrink ~4x on a 4-device data mesh
    with identical losses. Runs in a subprocess so the forced 4-CPU-device
    topology can't leak into the rest of the suite."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", ZERO1_SUBPROCESS],
                          env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK ratio=" in proc.stdout


def test_zero1_partition_spec_never_duplicates_axes():
    """Regression: a param already sharded over 'data' on one dim must not
    get 'data' again on a replicated dim (duplicate mesh axes crash
    NamedSharding for every MoE/FSDP-style param)."""
    from types import SimpleNamespace

    from jax.sharding import PartitionSpec

    from repro.layers import ParameterSpec as PSpec
    from repro.trainer.train_step import zero1_partition_spec

    mesh = SimpleNamespace(axis_names=("data", "model"),
                           shape={"data": 4, "model": 2})
    # Router-gate style ('data', None): dim1 divisible but data already used.
    spec = PSpec(shape=(8, 4), mesh_axes=("data", None))
    assert zero1_partition_spec(spec, mesh) == PartitionSpec("data", None)
    # Expert style ('model', ('pod','data'), None, None): nothing addable.
    spec = PSpec(shape=(2, 8, 16, 4),
                 mesh_axes=("model", ("pod", "data"), None, None))
    assert zero1_partition_spec(spec, mesh) == \
        PartitionSpec("model", "data", None, None)
    # Fully replicated param gets the data axes exactly once.
    spec = PSpec(shape=(8, 4), mesh_axes=None)
    assert zero1_partition_spec(spec, mesh) == PartitionSpec("data", None)
    # 'model'-only param: first divisible replicated dim picks up data.
    spec = PSpec(shape=(6, 8), mesh_axes=(None, "model"))
    assert zero1_partition_spec(spec, mesh) == PartitionSpec(None, "model")
    spec = PSpec(shape=(8, 6), mesh_axes=(None, "model"))
    assert zero1_partition_spec(spec, mesh) == PartitionSpec("data", "model")


def test_master_weights_make_bf16_param_storage_trainable():
    """fp32 master weights in the optimizer state: repeated updates smaller
    than one bf16 ulp must still accumulate (they vanish without masters)."""
    p = {"w": jnp.full((4,), 256.0, jnp.bfloat16)}  # ulp(256) = 2 in bf16
    g = {"w": jnp.full((4,), 1.0, jnp.float32)}
    naive = opt_lib.sgd(learning_rate=0.25)
    master = opt_lib.with_master_weights(opt_lib.sgd(learning_rate=0.25))

    def run(tx):
        params = dict(p)
        state = tx.init(params)
        for _ in range(8):  # 8 * 0.25 = 2.0 total
            updates, state = tx.update(g, state, params)
            params = {"w": (params["w"].astype(jnp.float32)
                            + updates["w"]).astype(jnp.bfloat16)}
        return float(params["w"][0])

    assert run(naive) == 256.0  # each -0.25 step rounds away: stalled
    assert run(master) == 254.0  # masters accumulate, then round
    # adamw grows the wrapper from config.
    tx = opt_lib.adamw(peak_lr=0.1, master_weight_dtype=jnp.float32)
    state = tx.init(p)
    assert isinstance(state, opt_lib.MasterWeightState)
    assert state.master["w"].dtype == jnp.float32


def test_optimizer_unit_behaviour():
    params = {"w": jnp.ones((4,)), "b": jnp.zeros((2,))}
    grads = {"w": jnp.full((4,), 2.0), "b": jnp.ones((2,))}
    tx = opt_lib.adamw(peak_lr=0.1, weight_decay=0.0, max_grad_norm=None)
    state = tx.init(params)
    updates, state = tx.update(grads, state, params)
    # First Adam step: update = -lr * sign-ish(grad).
    np.testing.assert_allclose(np.asarray(updates["w"]),
                               -0.1 * np.ones(4), rtol=1e-3)


def test_clip_by_global_norm():
    grads = {"w": jnp.full((4,), 10.0)}
    tx = opt_lib.clip_by_global_norm(1.0)
    out, _ = tx.update(grads, tx.init(grads), None)
    np.testing.assert_allclose(float(opt_lib.global_norm(out)), 1.0, rtol=1e-5)


def test_lr_schedule_shape():
    sched = opt_lib.linear_warmup_cosine(peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(sched(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(sched(jnp.asarray(10))), 1.0, rtol=1e-5)
    assert float(sched(jnp.asarray(100))) < 0.15
    assert float(sched(jnp.asarray(5))) == pytest.approx(0.5, rel=1e-5)
