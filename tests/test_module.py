"""Tests for Module + InvocationContext (paper §4.3, Figure 3)."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.config import REQUIRED, Required, config_class
from repro.core.module import Module, current_context, functional


class Leaf(Module):
    @config_class
    class Config(Module.Config):
        scale: float = 2.0

    def forward(self, x):
        # Summaries emitted without any ancestor knowing.
        self.add_summary("mean_in", jnp.mean(x))
        self.add_module_output("aux_loss", jnp.sum(x) * 0.0 + 1.0)
        return x * self.config.scale

    def stateful(self, x):
        w = self.state["w"]
        return x + w


class Parent(Module):
    @config_class
    class Config(Module.Config):
        child_a: Leaf.Config = Leaf.Config()
        child_b: Leaf.Config = Leaf.Config()

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        self._add_child("a", cfg.child_a)
        self._add_child("b", cfg.child_b)

    def forward(self, x):
        return self.a(x) + self.b(x)

    def randomized(self, x):
        ka = self.a.rand_key()
        kb = self.b.rand_key()
        return ka, kb


class RandLeaf(Module):
    def rand_key(self, *args):
        return jax.random.normal(self.prng_key, (2,))


def test_instantiate_tree_names_and_paths():
    cfg = Parent.default_config().set(name="root")
    root = cfg.instantiate()
    assert root.name == "root"
    assert root.a.name == "a" and root.a.path == "root.a"
    assert set(root.children) == {"a", "b"}


def test_functional_forward_and_summaries():
    cfg = Parent.default_config().set(name="root")
    cfg.child_a.scale = 3.0
    cfg.child_b.scale = 5.0
    root = cfg.instantiate()
    x = jnp.ones((4,))
    out, col = functional(root, state={}, inputs=(x,), is_training=True,
                          prng_key=jax.random.PRNGKey(0))
    assert jnp.allclose(out, 8.0 * x)
    # Summaries collected under per-child paths; parent code never mentioned them.
    assert "a/mean_in" in col.summaries and "b/mean_in" in col.summaries
    assert set(k for k in col.module_outputs) == {"a/aux_loss", "b/aux_loss"}


def test_state_routing():
    class Holder(Module):
        @config_class
        class Config(Module.Config):
            leaf: Leaf.Config = Leaf.Config()

        def __init__(self, cfg, *, parent=None):
            super().__init__(cfg, parent=parent)
            self._add_child("leaf", cfg.leaf)

        def forward(self, x):
            return self.leaf.stateful(x)

    root = Holder.default_config().set(name="h").instantiate()
    state = {"leaf": {"w": jnp.full((3,), 10.0)}}
    out, _ = functional(root, state=state, inputs=(jnp.zeros(3),))
    assert jnp.allclose(out, 10.0)


def test_prng_split_deterministic_and_distinct():
    class R(Module):
        @config_class
        class Config(Module.Config):
            a: RandLeaf.Config = RandLeaf.Config()
            b: RandLeaf.Config = RandLeaf.Config()

        def __init__(self, cfg, *, parent=None):
            super().__init__(cfg, parent=parent)
            self._add_child("a", cfg.a)
            self._add_child("b", cfg.b)

        def forward(self):
            return self.a.rand_key(), self.b.rand_key()

    root = R.default_config().set(name="r").instantiate()
    (ka1, kb1), _ = functional(root, state={}, inputs=(), prng_key=jax.random.PRNGKey(7))
    (ka2, kb2), _ = functional(root, state={}, inputs=(), prng_key=jax.random.PRNGKey(7))
    assert jnp.allclose(ka1, ka2) and jnp.allclose(kb1, kb2), "deterministic"
    assert not jnp.allclose(ka1, kb1), "children get distinct keys"


def test_no_context_raises():
    leaf = Leaf.default_config().set(name="l").instantiate()
    with pytest.raises(RuntimeError, match="InvocationContext"):
        leaf(jnp.ones(2))


def test_context_accessible_from_plain_function():
    """Contexts reference modules, not vice-versa: 3rd-party code can reach them."""

    def third_party_helper():
        ctx = current_context()
        assert ctx is not None
        ctx.add_summary("from_outside", 42)
        return 0

    class M(Module):
        def forward(self, x):
            third_party_helper()
            return x

    m = M.default_config().set(name="m").instantiate()
    _, col = functional(m, state={}, inputs=(jnp.zeros(1),))
    assert col.summaries.get("from_outside") == 42


def test_jit_and_grad_compatible():
    class Lin(Module):
        def forward(self, x):
            return jnp.sum(self.state["w"] * x)

    m = Lin.default_config().set(name="lin").instantiate()

    def loss(state, x):
        out, _ = functional(m, state=state, inputs=(x,))
        return out

    g = jax.jit(jax.grad(loss))({"w": jnp.ones(3)}, jnp.arange(3.0))
    assert jnp.allclose(g["w"], jnp.arange(3.0))


def test_reentrant_same_module_method():
    class M(Module):
        def helper(self, x):
            return x + 1

        def forward(self, x):
            # Public method call on self should not push a duplicate frame.
            return self.helper(x) * 2

    m = M.default_config().set(name="m").instantiate()
    out, _ = functional(m, state={}, inputs=(jnp.array(1.0),))
    assert out == 4.0


def test_duplicate_child_rejected():
    class M(Module):
        @config_class
        class Config(Module.Config):
            leaf: Leaf.Config = Leaf.Config()

        def __init__(self, cfg, *, parent=None):
            super().__init__(cfg, parent=parent)
            self._add_child("x", cfg.leaf)
            self._add_child("x", cfg.leaf)

    with pytest.raises(ValueError, match="Duplicate child"):
        M.default_config().set(name="m").instantiate()
