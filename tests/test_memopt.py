"""Memory-frugal training subsystem tests (src/repro/memopt/).

Covers the three pillars — factored second moments (Adafactor / SM3),
quantized Adam EMA storage (``adamw(state_dtype=...)``), reversible
residual stacks — plus the MemoryModifier/mesh-rule wiring, the exact
state-bytes accounting, ZeRO-1 composition (subprocess, forced 4-device
mesh), and the subsystem's own grep contract (state-dtype name
interpretation must not leak out of memopt/).
"""

import os
import pathlib
import re
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import config_for_function
from repro.core.module import functional
from repro.layers import (
    CausalLM,
    Decoder,
    FeedForward,
    Repeat,
    TransformerLayer,
)
from repro.memopt import (
    accounting,
    factored,
    state_quant,
)
from repro.memopt.modifier import MemoryModifier
from repro.memopt.reversible import rev_stack, validate_reversible
from repro.trainer import optimizers as opt_lib
from repro.trainer.trainer import SpmdTrainer

# Toy param tree: one stacked matrix (factorable) + one bias (not).
_TOY_PARAMS = {
    "w": jnp.zeros((4, 64, 32), jnp.float32),
    "b": jnp.zeros((32,), jnp.float32),
}


def _opt_state_bytes(tx, params=_TOY_PARAMS):
    return accounting.state_bytes(tx.init(params))


# ------------------------- state bytes / accounting --------------------------


def test_state_bytes_ratios():
    """The headline memory numbers, measured on real init states: bf16
    halves, int8 quarters (minus scales) the Adam EMA bytes; factored
    optimizers drop them by orders of magnitude."""
    base = _opt_state_bytes(opt_lib.adamw())
    bf16 = _opt_state_bytes(opt_lib.adamw(state_dtype="bf16"))
    int8 = _opt_state_bytes(opt_lib.adamw(state_dtype="int8"))
    ada = _opt_state_bytes(factored.adafactor())
    sm3 = _opt_state_bytes(factored.sm3())
    assert base / bf16 >= 1.9, (base, bf16)
    assert base / int8 >= 3.0, (base, int8)
    assert base / ada >= 3.0, (base, ada)
    assert base / sm3 >= 3.0, (base, sm3)
    # fp32 by name is exactly the legacy layout.
    assert _opt_state_bytes(opt_lib.adamw(state_dtype="fp32")) == base


def test_per_leaf_state_bytes():
    per_leaf = accounting.per_leaf_state_bytes(
        opt_lib.adamw().init(_TOY_PARAMS))
    assert sum(per_leaf.values()) == _opt_state_bytes(opt_lib.adamw())
    assert all(isinstance(k, str) and v > 0 for k, v in per_leaf.items())


def test_accounting_works_on_shape_structs():
    """The trainer accounts on eval_shape output (no buffers materialized)."""
    tx = opt_lib.adamw(state_dtype="int8")
    shapes = jax.eval_shape(tx.init, _TOY_PARAMS)
    assert accounting.state_bytes(shapes) == _opt_state_bytes(tx)


# --------------------------- factored optimizers -----------------------------


def test_adafactor_state_shapes():
    state = factored.scale_by_factored_rms().init(_TOY_PARAMS)
    # Flattened leaf order: b (0), w (1). w factors into row/col EMAs with
    # the stacked leading axis kept as a batch dim; b keeps a full moment.
    assert state.v_row["0001"].shape == (4, 64)
    assert state.v_col["0001"].shape == (4, 32)
    assert state.v_full["0000"].shape == (32,)
    assert "0000" not in state.v_row


def test_sm3_state_shapes():
    state = factored.scale_by_sm3().init(_TOY_PARAMS)
    accs_w = state.accumulators["0001"]
    assert {k: v.shape for k, v in accs_w.items()} == {
        "0": (4,), "1": (64,), "2": (32,)}
    assert state.accumulators["0000"]["0"].shape == (32,)


@pytest.mark.parametrize("name,make", [
    ("adamw", lambda: opt_lib.adamw(peak_lr=0.05)),
    ("adamw-bf16", lambda: opt_lib.adamw(peak_lr=0.05, state_dtype="bf16")),
    ("adamw-int8", lambda: opt_lib.adamw(peak_lr=0.05, state_dtype="int8")),
    ("adafactor", lambda: factored.adafactor(peak_lr=0.3)),
    ("sm3", lambda: factored.sm3(peak_lr=0.5)),
])
def test_optimizer_reduces_quadratic_loss(name, make):
    """Every memopt optimizer actually optimizes (shared quadratic)."""
    target = jax.random.normal(jax.random.PRNGKey(0), (16, 16))
    params = {"w": jnp.zeros((16, 16), jnp.float32)}
    loss_fn = lambda p: jnp.mean((p["w"] - target) ** 2)  # noqa: E731
    tx = make()
    state = tx.init(params)

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, state = tx.update(grads, state, params)
        return jax.tree.map(jnp.add, params, updates), state, loss

    first = None
    for _ in range(60):
        params, state, loss = step(params, state)
        first = loss if first is None else first
    assert float(loss) < 0.5 * float(first), (name, first, loss)


def test_int8_adam_first_step_matches_fp32():
    """Quantization error enters only through the *carried* state: from a
    zero state, the int8 transform's first update is bit-for-bit the fp32
    Adam update (EMA math runs fp32 on freshly dequantized values)."""
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (8, 16)),
             "b": jax.random.normal(jax.random.PRNGKey(2), (16,))}
    params = jax.tree.map(jnp.zeros_like, grads)
    ref = opt_lib.scale_by_adam()
    q = state_quant.scale_by_adam_int8()
    u_ref, _ = ref.update(grads, ref.init(params), params)
    u_q, _ = q.update(grads, q.init(params), params)
    for k in grads:
        np.testing.assert_allclose(u_q[k], u_ref[k], atol=1e-6)


def test_int8_adam_converges_with_quantization_drag():
    """int8 moments optimize the same quadratic, slower: per-row symmetric
    quantization zeroes sub-resolution moment entries, which a tiny
    deterministic quadratic amplifies far more than real training (LM-level
    loss parity is asserted in BENCH_train.json's memopt block, ~1% at 60
    steps). The contract here: steady convergence, bounded drag."""
    target = jax.random.normal(jax.random.PRNGKey(0), (16, 16))
    loss_fn = lambda p: jnp.mean((p["w"] - target) ** 2)  # noqa: E731
    losses = {}
    initial = float(loss_fn({"w": jnp.zeros((16, 16), jnp.float32)}))
    for name, tx in [("fp32", opt_lib.adamw(peak_lr=0.05)),
                     ("int8", opt_lib.adamw(peak_lr=0.05,
                                            state_dtype="int8"))]:
        params = {"w": jnp.zeros((16, 16), jnp.float32)}
        state = tx.init(params)
        for _ in range(60):
            loss, grads = jax.value_and_grad(loss_fn)(params)
            updates, state = tx.update(grads, state, params)
            params = jax.tree.map(jnp.add, params, updates)
        losses[name] = float(loss)
    assert losses["fp32"] < 0.01 * initial, losses
    assert losses["int8"] < 0.25 * initial, losses


def test_resolve_state_dtype_rejects_unknown():
    with pytest.raises(ValueError, match="state_dtype"):
        state_quant.resolve_state_dtype("fp4")


def test_master_weights_compose_with_quantized_state():
    """bf16 params + fp32 masters + int8 moments: the full mixed-precision
    memory recipe in one optimizer config."""
    tx = opt_lib.adamw(peak_lr=0.05, state_dtype="int8",
                       master_weight_dtype=jnp.float32)
    params = {"w": jnp.zeros((8, 16), jnp.bfloat16)}
    state = tx.init(params)
    assert isinstance(state, opt_lib.MasterWeightState)
    assert state.master["w"].dtype == jnp.float32
    grads = {"w": jnp.ones((8, 16), jnp.bfloat16)}
    updates, state = tx.update(grads, state, params)
    params = jax.tree.map(lambda p, u: (p + u).astype(p.dtype),
                          params, updates)
    assert jnp.all(jnp.isfinite(params["w"].astype(jnp.float32)))
    # Int8 moments live inside the wrapped chain state.
    int8_leaves = [l for l in jax.tree.leaves(state)
                   if l.dtype == jnp.int8]
    assert int8_leaves, "no quantized moment buffers in the state"


# ----------------------------- chain validation ------------------------------


def test_chain_rejects_wrong_state_arity():
    tx = opt_lib.chain(opt_lib.scale_by_adam(), opt_lib.scale(1.0))
    params = {"w": jnp.zeros((4,))}
    state = tx.init(params)
    with pytest.raises(ValueError, match="chain\\(\\) of 2 transforms"):
        tx.update(params, state[:1], params)
    with pytest.raises(ValueError, match="chain\\(\\) of 2 transforms"):
        tx.update(params, {"not": "a tuple"}, params)


def test_chain_rejects_foreign_state_structure():
    """Restoring an adafactor checkpoint into an adamw chain must fail with
    a config-mismatch message, not a deep tree-map structure error."""
    params = {"w": jnp.zeros((16, 16))}
    adam = opt_lib.adamw()
    ada = factored.adafactor()
    with pytest.raises(ValueError, match="different optimizer config"):
        adam.update(params, ada.init(params), params)


# ------------------------------- reversible ----------------------------------


def _layer_cfg(dim=32):
    layer = TransformerLayer.default_config().set(input_dim=dim)
    layer.self_attention.set(num_heads=4, num_kv_heads=2)
    layer.feed_forward.set(hidden_dim=2 * dim)
    return layer


def _rev_repeat(num_layers=2, dim=32, **kw):
    return Repeat.default_config().set(
        name="stack", layer=_layer_cfg(dim), num_layers=num_layers,
        remat_policy=None, reversible=True, **kw)


def test_rev_stack_inverts_and_matches_autodiff():
    rep = _rev_repeat().instantiate()
    state = rep.initialize_parameters_recursively(jax.random.PRNGKey(0))
    stacked = state["layer"]
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    pos = jnp.arange(8)[None, :].repeat(2, axis=0)

    def run(params, x1, x2, use_custom_vjp):
        y1, y2 = rev_stack(rep.layer, params, x1, x2, pos,
                           is_training=False,
                           use_custom_vjp=use_custom_vjp)
        return jnp.sum(jnp.cos(y1) + jnp.sin(y2))

    val_c, grads_c = jax.value_and_grad(run, argnums=(0, 1, 2))(
        stacked, x, x, True)
    val_r, grads_r = jax.value_and_grad(run, argnums=(0, 1, 2))(
        stacked, x, x, False)
    np.testing.assert_allclose(val_c, val_r, rtol=1e-5)
    for gc, gr in zip(jax.tree.leaves(grads_c), jax.tree.leaves(grads_r)):
        # fp32 + one extra residual-add rounding per inverted layer.
        np.testing.assert_allclose(gc, gr, rtol=5e-3, atol=5e-5)

    # Explicit inversion: reconstruct the inputs from the outputs alone.
    y1, y2 = rev_stack(rep.layer, stacked, x, x, pos, is_training=False)
    h1, h2 = y1, y2
    for i in reversed(range(2)):
        p_i = jax.tree.map(lambda a: a[i], stacked)

        def branch(method, h):
            inputs = {"x": h}
            if method == "attn_branch":
                inputs["positions"] = pos
            out, _ = functional(rep.layer, state=p_i, inputs=inputs,
                                prng_key=None, is_training=False,
                                method=method)
            return out

        h2 = h2 - branch("ffn_branch", h1)
        h1 = h1 - branch("attn_branch", h2)
    np.testing.assert_allclose(h1, x, atol=5e-5)
    np.testing.assert_allclose(h2, x, atol=5e-5)


def test_reversible_repeat_forward_runs_and_differs_from_plain():
    rep = _rev_repeat().instantiate()
    state = rep.initialize_parameters_recursively(jax.random.PRNGKey(0))
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    out, _ = functional(rep, state=state, inputs=(x,), is_training=False)
    assert out.shape == x.shape
    assert jnp.all(jnp.isfinite(out))
    plain = _rev_repeat().set(reversible=False).instantiate()
    out_plain, _ = functional(plain, state=state, inputs=(x,),
                              is_training=False)
    # Same weights, different (two-stream) computation graph.
    assert not np.allclose(out, out_plain)


def test_reversible_rejects_residual_dropout():
    cfg = _rev_repeat()
    cfg.layer.set(residual_dropout=0.1)
    with pytest.raises(ValueError, match="residual_dropout"):
        cfg.instantiate()


def test_reversible_rejects_non_decomposable_layer():
    ffn = FeedForward.default_config().set(input_dim=32, hidden_dim=64)
    cfg = Repeat.default_config().set(
        name="stack", layer=ffn, num_layers=2, remat_policy=None,
        reversible=True)
    with pytest.raises(ValueError, match="attn_branch"):
        cfg.instantiate()
    # The same layout is fine when not reversible.
    validate_reversible(_rev_repeat().instantiate().layer)


def test_reversible_decode_interface_raises():
    rep = _rev_repeat().instantiate()
    state = rep.initialize_parameters_recursively(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="reversible"):
        functional(rep, state=state, inputs=(2, 8), is_training=False,
                   method="init_states")


# ------------------------- MemoryModifier / mesh rules -----------------------


def _tiny_trainer_cfg(*, steps=4, zero1=True):
    model = CausalLM.default_config().set(
        decoder=Decoder.default_config().set(
            vocab_size=32, dim=32,
            stack=Repeat.default_config().set(
                layer=_layer_cfg(32), num_layers=2, remat_policy=None)))
    cfg = SpmdTrainer.default_config().set(
        name="t", model=model, max_steps=steps, log_every_n=steps, seed=0)
    cfg.input.set(task="lm", vocab_size=32, seq_len=16, global_batch_size=4)
    cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(
        peak_lr=1e-2, weight_decay=0.01)
    if zero1:
        cfg.opt_state_sharding = "zero1"
    return cfg


def _apply(cfg, **kw):
    return MemoryModifier.default_config().set(**kw).instantiate().apply(cfg)


def test_memory_modifier_swaps_optimizer_and_carries_tuning():
    cfg = _apply(_tiny_trainer_cfg(), optimizer="adafactor")
    opt = cfg.learner.optimizer
    assert type(opt)._fn is factored.adafactor
    # Experiment tuning (LR, decay) survives the swap; memory knobs change.
    assert opt.peak_lr == 1e-2
    assert opt.weight_decay == 0.01


def test_memory_modifier_state_dtype_and_reversible():
    cfg = _apply(_tiny_trainer_cfg(), state_dtype="bf16", reversible=True)
    assert cfg.learner.optimizer.state_dtype == "bf16"
    assert cfg.model.decoder.stack.reversible is True


def test_memory_modifier_rejects_state_dtype_on_factored():
    cfg = _apply(_tiny_trainer_cfg(), optimizer="sm3")
    with pytest.raises(ValueError, match="state_dtype"):
        _apply(cfg, state_dtype="int8")


def test_memory_modifier_rejects_unknown_optimizer():
    with pytest.raises(ValueError, match="adafactor"):
        _apply(_tiny_trainer_cfg(), optimizer="lion")


def test_frugal_mesh_rules_compose_the_recipe():
    """One instance-type suffix turns on the whole memory-frugal recipe at
    config level (zero model-code changes, paper §4.2)."""
    from repro.launch.train import MESH_RULES
    from repro.trainer.mesh_rules import apply_mesh_rules

    cfg = apply_mesh_rules(_tiny_trainer_cfg(),
                           instance_type="tpu-v5e-256-frugal",
                           rules=MESH_RULES)
    assert cfg.learner.optimizer.state_dtype == "bf16"
    assert cfg.model.decoder.stack.reversible is True
    assert cfg.opt_state_sharding == "zero1"

    cfg = apply_mesh_rules(_tiny_trainer_cfg(),
                           instance_type="tpu-v5e-256-frugal-max",
                           rules=MESH_RULES)
    assert type(cfg.learner.optimizer)._fn is factored.adafactor
    assert cfg.model.decoder.stack.reversible is True


# ----------------------- trainer integration (compile) -----------------------


@pytest.mark.heavy
@pytest.mark.parametrize("memopt", [
    {"state_dtype": "bf16"},
    {"state_dtype": "int8"},
    {"optimizer": "adafactor"},
    {"optimizer": "sm3"},
    {"reversible": True},
])
def test_trainer_memopt_zero1_compiles_once(memopt):
    """Each memopt axis composes with ZeRO-1 end to end: the trainer runs,
    loss is finite, the exported opt-state accounting matches an
    independent eval_shape measurement, and the train step compiles exactly
    once (no retraces from the quantize/requantize or custom_vjp paths)."""
    cfg = _apply(_tiny_trainer_cfg(steps=4), **memopt)
    trainer = cfg.instantiate()
    result = trainer.run()
    assert np.isfinite(result["final"]["loss"])
    expected = accounting.state_bytes(
        jax.eval_shape(trainer.init_state)["opt_state"])
    assert result["opt_state_bytes"] == expected
    assert trainer._jit_step._cache_size() == 1, \
        f"memopt={memopt} retraced the train step"


# ----------------- ZeRO-1 x master weights x quantized state -----------------


MEMOPT_ZERO1_SUBPROCESS = textwrap.dedent("""
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.core.config import config_for_function, update_configs_recursively
    from repro.layers import CausalLM, Decoder, Repeat, TransformerLayer
    from repro.trainer import optimizers as opt_lib
    from repro.trainer.trainer import SpmdTrainer

    PART_FIELDS = ["weight_partition", "qkv_weight_partition",
                   "out_weight_partition", "up_weight_partition",
                   "down_weight_partition", "gate_weight_partition"]

    def make(state_dtype):
        layer = TransformerLayer.default_config().set(input_dim=32)
        layer.self_attention.set(num_heads=4, num_kv_heads=2)
        layer.feed_forward.set(hidden_dim=64)
        model = CausalLM.default_config().set(
            decoder=Decoder.default_config().set(
                vocab_size=32, dim=32,
                stack=Repeat.default_config().set(
                    layer=layer, num_layers=2, remat_policy=None)))
        cfg = SpmdTrainer.default_config().set(
            name="t", model=model, max_steps=2, log_every_n=1, seed=1,
            mesh_shape=(4,), mesh_axis_names=("data",))
        update_configs_recursively(cfg.model, {f: None for f in PART_FIELDS})
        cfg.input.set(task="lm", vocab_size=32, seq_len=16, global_batch_size=8)
        cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(
            peak_lr=1e-2, state_dtype=state_dtype,
            master_weight_dtype=jnp.float32)
        cfg.opt_state_sharding = "zero1"
        return cfg

    def per_device_opt_bytes(state, shardings):
        total = 0
        for leaf, sh in zip(jax.tree.leaves(state["opt_state"]),
                            jax.tree.leaves(shardings["opt_state"])):
            total += int(np.prod(sh.shard_shape(leaf.shape))) * leaf.dtype.itemsize
        return total

    out = {}
    for state_dtype in ("fp32", "bf16", "int8"):
        trainer = make(state_dtype).instantiate()
        res = trainer.run()
        state = res["state"]
        shardings = trainer.state_shardings(jax.eval_shape(lambda: state))
        for leaf, sh in zip(jax.tree.leaves(state["opt_state"]),
                            jax.tree.leaves(shardings["opt_state"])):
            assert leaf.sharding == sh, (leaf.shape, leaf.sharding, sh)
        if state_dtype == "int8":
            # The quantized EMA leaves themselves must be ZeRO-1 sharded
            # (param-structured trees keep the data-axis layout) ...
            q = [l for l in jax.tree.leaves(state["opt_state"])
                 if l.dtype == jnp.int8]
            assert q, "no int8 moment leaves in the optimizer state"
            q_total = sum(l.size for l in q)
            q_dev = sum(int(np.prod(l.sharding.shard_shape(l.shape)))
                        for l in q)
            assert q_total / q_dev > 2.0, (q_total, q_dev)
            # ... while the fp32 scale dicts (non-param-structured) stay
            # replicated — tiny, and structurally unshardable by zero1.
            scales = [l for l in jax.tree.leaves(state["opt_state"])
                      if l.dtype == jnp.float32 and l.ndim >= 1
                      and l.shape[-1:] == (1,)]
            assert scales, "no per-row scale leaves found"
            for l in scales:
                assert l.sharding.shard_shape(l.shape) == l.shape
        out[state_dtype] = (per_device_opt_bytes(state, shardings),
                            float(res["final"]["loss"]))
    # fp32: mu+nu+master = 12B/param sharded; bf16: 8B; int8: ~6B + scales.
    r_bf16 = out["fp32"][0] / out["bf16"][0]
    r_int8 = out["fp32"][0] / out["int8"][0]
    assert 1.3 < r_bf16 < 1.7, (out, r_bf16)
    assert r_int8 > 1.5, (out, r_int8)
    rel = abs(out["bf16"][1] - out["fp32"][1]) / max(abs(out["fp32"][1]), 1e-9)
    assert rel < 0.05, out
    print(f"OK r_bf16={r_bf16:.3f} r_int8={r_int8:.3f}")
""")


@pytest.mark.heavy(timeout=420)
def test_zero1_master_weights_quantized_state_sharding():
    """ZeRO-1 x fp32 masters x bf16/int8 moments on a forced 4-CPU-device
    mesh: quantized EMA leaves stay data-sharded, scales stay replicated,
    and per-device optimizer bytes drop by the dtype-arithmetic factors.
    Subprocess so the forced topology can't leak into the suite."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = (os.path.abspath(src) + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", MEMOPT_ZERO1_SUBPROCESS],
                          env=env, capture_output=True, text=True,
                          timeout=360)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK r_bf16=" in proc.stdout


# ------------------------------ grep contract --------------------------------


def test_state_dtype_names_confined_to_memopt():
    """Optimizer state-dtype *names* are config surface everywhere, but
    their interpretation (name -> storage dtype / quantized layout) lives
    ONLY in repro.memopt. Mirrors the quantization grep contract."""
    src = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"
    pattern = re.compile(
        r"state_dtype\s*==|state_dtype\s+in\s|resolve_state_dtype\(")
    offenders = []
    for path in sorted(src.rglob("*.py")):
        rel = path.relative_to(src).as_posix()
        if rel.startswith("memopt/"):
            continue
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            if pattern.search(line):
                offenders.append(f"{rel}:{lineno}: {line.strip()}")
    assert not offenders, (
        "state-dtype interpretation escaped the memopt subsystem:\n"
        + "\n".join(offenders))
