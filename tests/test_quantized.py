"""Int8 DotGeneral-swap quantization (paper §4.2 + App. A) and fp8 KV cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import config_for_function, replace_config
from repro.core.module import functional
from repro.layers import CausalLM, Decoder, Linear, Repeat, TransformerLayer
from repro.layers.quantized import Int8ConfigModifier, QuantizedLinear, quantize_int8


def test_quantize_int8_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    q, s = quantize_int8(x, axis=-1)
    deq = q.astype(jnp.float32) * s
    err = jnp.max(jnp.abs(deq - x))
    assert float(err) <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6


def test_quantized_linear_close_to_fp():
    cfg = Linear.default_config().set(name="l", input_dim=64, output_dim=32)
    layer = cfg.instantiate()
    state = layer.initialize_parameters_recursively(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    fp, _ = functional(layer, state=state, inputs=(x,))

    qcfg = QuantizedLinear.default_config().set(
        name="q", input_dim=64, output_dim=32)
    qlayer = qcfg.instantiate()
    q_out, _ = functional(qlayer, state=state, inputs=(x,))  # same checkpoint!
    rel = np.linalg.norm(np.asarray(q_out - fp)) / np.linalg.norm(np.asarray(fp))
    assert rel < 0.02, f"int8 relative error {rel}"


def test_quantized_linear_ste_gradients_flow():
    qcfg = QuantizedLinear.default_config().set(
        name="q", input_dim=16, output_dim=8)
    layer = qcfg.instantiate()
    state = layer.initialize_parameters_recursively(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16))

    def loss(s):
        out, _ = functional(layer, state=s, inputs=(x,), is_training=True,
                            prng_key=jax.random.PRNGKey(2))
        return jnp.sum(out ** 2)

    g = jax.grad(loss)(state)
    assert float(jnp.linalg.norm(g["weight"])) > 0, "STE must pass gradients"


def _tiny_trainer_cfg():
    from repro.trainer import optimizers as opt_lib
    from repro.trainer.trainer import SpmdTrainer

    layer = TransformerLayer.default_config().set(input_dim=32)
    layer.self_attention.set(num_heads=4)
    layer.feed_forward.set(hidden_dim=64)
    model = CausalLM.default_config().set(
        decoder=Decoder.default_config().set(
            vocab_size=64, dim=32,
            stack=Repeat.default_config().set(layer=layer, num_layers=2,
                                              remat_policy=None)))
    cfg = SpmdTrainer.default_config().set(name="t", model=model, max_steps=8,
                                           log_every_n=4)
    cfg.input.set(task="lm", vocab_size=64, seq_len=16, global_batch_size=4)
    cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(peak_lr=1e-3)
    return cfg


def test_int8_modifier_swaps_all_linears_and_trains():
    """The paper's quantization story end-to-end: one modifier swaps every
    Linear in the experiment; training still converges finitely."""
    cfg = _tiny_trainer_cfg()
    cfg = Int8ConfigModifier.default_config().instantiate().apply(cfg)
    # every Linear is now QuantizedLinear (q/k/v/o + lm head path if untied)
    from repro.core.config import visit_config

    kinds = []
    visit_config(cfg, lambda p, c: kinds.append(type(c).__qualname__))
    assert not any(k == "Linear.Config" for k in kinds)
    assert any("QuantizedLinear" in k for k in kinds)
    result = cfg.instantiate().run()
    assert np.isfinite(result["final"]["loss"])


def test_fp8_kv_cache_decode_close_to_bf16():
    """Hillclimb variant semantics: fp8(e4m3) cache decode stays close to the
    fp32-cache decode (argmax tokens may differ slightly; logits are close)."""
    from repro.layers import MultiheadAttention

    cfg = MultiheadAttention.default_config().set(
        name="a", input_dim=64, num_heads=4, num_kv_heads=2,
        kv_cache_dtype=jnp.float32)
    layer = cfg.instantiate()
    state = layer.initialize_parameters_recursively(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 64)) * 0.5

    def decode(c):
        l = c.instantiate()
        cache, _ = functional(l, state=state, inputs=(2, 16), method="init_states")
        (cache, y0), _ = functional(l, state=state,
                                    inputs={"state": cache, "x": x[:, :8]},
                                    method="prefill")
        (cache, y1), _ = functional(l, state=state,
                                    inputs={"state": cache, "x_step": x[:, 8:]},
                                    method="extend_step")
        return jnp.concatenate([y0, y1], axis=1)

    ref = decode(cfg)
    f8 = decode(cfg.clone(kv_cache_dtype=jnp.float8_e4m3fn))
    rel = np.linalg.norm(np.asarray(f8 - ref)) / np.linalg.norm(np.asarray(ref))
    assert rel < 0.06, f"fp8 cache relative error {rel}"
